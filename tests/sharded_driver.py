"""Subprocess driver for multi-device tests (8 fake host devices).

Usage: python sharded_driver.py <case>
"""

import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map


def case_engine():
    """Predicate-sharded serve Plan (the compiled-plan API end to end):
    ``Engine.compile(ServeQ, ExecConfig(mesh=...))`` == truth."""
    from repro.core import engine as eng, k2triples
    from repro.core.query import ExecConfig, ServeQ
    from repro.data import rdf

    ds = rdf.generate(2000, n_subjects=100, n_preds=7, n_objects=120, seed=3)
    store = k2triples.from_id_triples(
        ds.ids, n_so=ds.n_so, n_subjects=ds.n_subjects,
        n_objects=ds.n_objects, n_preds=ds.n_preds,
    )
    T = set(map(tuple, ds.ids.tolist()))
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    E = eng.Engine(store)
    plan = E.compile(ServeQ(unbounded=False), ExecConfig.from_env(cap=256, mesh=mesh))
    rng = np.random.default_rng(0)
    B = 32
    ops = rng.integers(0, 3, B).astype(np.int32)
    ids = ds.ids[rng.integers(0, ds.n_triples, B)]
    q = eng.ServeBatch(
        op=jnp.asarray(ops), s=jnp.asarray(ids[:, 0], jnp.int32),
        p=jnp.asarray(ids[:, 1], jnp.int32), o=jnp.asarray(ids[:, 2], jnp.int32),
    )
    r = plan(q)
    hit, rids, valid = np.asarray(r.hit), np.asarray(r.ids), np.asarray(r.valid)
    for i in range(B):
        s_, p_, o_ = map(int, ids[i])
        if ops[i] == 0:
            assert hit[i] == ((s_, p_, o_) in T), i
        elif ops[i] == 1:
            assert rids[i][valid[i]].tolist() == sorted(
                oo for (ss, pp, oo) in T if ss == s_ and pp == p_
            ), i
        else:
            assert rids[i][valid[i]].tolist() == sorted(
                ss for (ss, pp, oo) in T if pp == p_ and oo == o_
            ), i
    # unbounded-predicate sweep (the paper's worst case, parallelized) —
    # kept on the reference entry point: it is the index-free fallback
    f_pad = eng.pad_preds(store.forest, 4)
    f_sh = eng.shard_forest(f_pad, mesh, "model")
    unb = eng.make_sharded_unbounded_scan(store.meta, mesh, cap=128)
    keys = jnp.asarray(ids[:8, 0], jnp.int32)
    axes = jnp.zeros((8,), jnp.int32)
    ids_u, valid_u, _ = (np.asarray(x) for x in unb(f_sh, keys, axes))
    for i in range(8):
        s_ = int(ids[i, 0])
        for pp in range(f_pad.n_preds):
            got = ids_u[i, pp][valid_u[i, pp]].tolist()
            exp = (
                sorted(oo for (ss, p2, oo) in T if ss == s_ and p2 == pp + 1)
                if pp < ds.n_preds else []
            )
            assert got == exp, (i, pp)
    # no arena-sized all-gathers in the compiled module
    txt = plan.compiled_text(q)
    assert txt.count("all-gather") == 0
    print("engine OK")


def case_engine_pruned():
    """Index-pruned unbounded serve IR on a predicate-sharded forest, via
    the compiled-plan API: sharded Plan == single-device Plan == truth."""
    from repro.core import engine as eng, k2triples
    from repro.core.query import ExecConfig, ServeQ
    from repro.data import rdf

    ds = rdf.generate(
        3000, n_subjects=90, n_preds=16, n_objects=110,
        preds_per_subject=4, seed=6,
    )
    store = k2triples.from_id_triples(
        ds.ids, n_so=ds.n_so, n_subjects=ds.n_subjects,
        n_objects=ds.n_objects, n_preds=ds.n_preds,
    )
    T = set(map(tuple, ds.ids.tolist()))
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    E = eng.Engine(store)
    plan_sh = E.compile(ServeQ(), ExecConfig.from_env(cap=128, mesh=mesh))
    plan_1d = E.compile(ServeQ(), ExecConfig.from_env(cap=128))
    rng = np.random.default_rng(1)
    B = 32
    ops = rng.integers(0, 6, B).astype(np.int32)
    ids = ds.ids[rng.integers(0, ds.n_triples, B)]
    q = eng.ServeBatch(
        op=jnp.asarray(ops), s=jnp.asarray(ids[:, 0], jnp.int32),
        p=jnp.asarray(np.where(ops >= 3, 0, ids[:, 1]), jnp.int32),
        o=jnp.asarray(ids[:, 2], jnp.int32),
    )
    r = plan_sh(q)
    ref = plan_1d(q)
    for name, a, b in zip(r._fields, r, ref):
        assert (np.asarray(a) == np.asarray(b)).all(), name
    # spot-check against truth: every unbounded pair lane
    up, ui, uv = (np.asarray(x) for x in (r.u_preds, r.u_ids, r.u_valid))
    for i in range(B):
        if ops[i] not in (3, 4):
            continue
        key = int(ids[i, 0] if ops[i] == 3 else ids[i, 2])
        got = {
            int(up[i, l]): ui[i, l][uv[i, l]].tolist()
            for l in range(up.shape[1]) if up[i, l] and uv[i, l].any()
        }
        exp = {}
        for (ss, pp, oo) in T:
            if ops[i] == 3 and ss == key:
                exp.setdefault(pp, []).append(oo)
            if ops[i] == 4 and oo == key:
                exp.setdefault(pp, []).append(ss)
        assert got == {k: sorted(v) for k, v in exp.items()}, i
    # a pattern plan on the same mesh config: lanes pad to the data axis
    # and decode from the psum'd u_* block
    from repro.core.query import TriplePatternQ

    s0 = int(ids[0, 0])
    got = E.compile(
        TriplePatternQ(s0, "?p", "?o"), ExecConfig.from_env(cap=128, mesh=mesh)
    )()
    exp = {}
    for (ss, pp, oo) in T:
        if ss == s0:
            exp.setdefault(pp, []).append(oo)
    assert {k: v.tolist() for k, v in got.items()} == {
        k: sorted(v) for k, v in exp.items()
    }
    # the pruned path reduces [B, u_width, cap]; the wire never carries
    # an arena- or P-sized gather
    txt = plan_sh.compiled_text(q)
    assert txt.count("all-gather") == 0
    print("engine_pruned OK")


def case_compress():
    """int8 EF all-reduce: shared scale is exact-sum; EF kills bias."""
    from repro.dist import compress

    mesh = jax.make_mesh((8,), ("data",))
    rng = np.random.default_rng(0)
    g_all = rng.standard_normal((8, 256)).astype(np.float32)

    fn = jax.jit(
        shard_map(
            lambda g, e: compress.compress_decompress_psum(g, e, "data"),
            mesh=mesh, in_specs=(P("data"), P("data")), out_specs=(P("data"), P("data")),
        )
    )
    exact = g_all.mean(axis=0)
    out, err = fn(jnp.asarray(g_all.reshape(-1)), jnp.zeros(8 * 256))
    e1 = np.abs(np.asarray(out).reshape(8, 256)[0] - exact).max()
    assert e1 < 0.05, e1
    errbuf = jnp.zeros((8 * 256,))
    acc = np.zeros(256)
    N = 20
    for _ in range(N):
        o, errbuf = fn(jnp.asarray(g_all.reshape(-1)), errbuf)
        acc += np.asarray(o).reshape(8, 256)[0]
    e2 = np.abs(acc / N - exact).max()
    assert e2 < e1 * 0.3, (e1, e2)
    print("compress OK")


def case_sortedset_union():
    """Sharded serve batch at 8 devices with non-uniform predicate load."""
    from repro.core import engine as eng, k2triples
    from repro.data import rdf

    from repro.core.query import ExecConfig, ServeQ

    ds = rdf.generate(4000, n_subjects=80, n_preds=16, n_objects=90, seed=9)
    store = k2triples.from_id_triples(
        ds.ids, n_so=ds.n_so, n_subjects=ds.n_subjects,
        n_objects=ds.n_objects, n_preds=ds.n_preds,
    )
    mesh = jax.make_mesh((1, 8), ("data", "model"))
    T = set(map(tuple, ds.ids.tolist()))
    plan = eng.Engine(store).compile(
        ServeQ(unbounded=False), ExecConfig.from_env(cap=512, mesh=mesh)
    )
    ids = ds.ids[:64]
    q = eng.ServeBatch(
        op=jnp.full((64,), 1, jnp.int32), s=jnp.asarray(ids[:, 0], jnp.int32),
        p=jnp.asarray(ids[:, 1], jnp.int32), o=jnp.asarray(ids[:, 2], jnp.int32),
    )
    r = plan(q)
    rids, valid = np.asarray(r.ids), np.asarray(r.valid)
    for i in range(64):
        s_, p_, _ = map(int, ids[i])
        assert rids[i][valid[i]].tolist() == sorted(
            oo for (ss, pp, oo) in T if ss == s_ and pp == p_
        )
    print("sortedset_union OK")


def case_moe_shmap():
    """shard_map MoE == single-device reference MoE (same routing math)."""
    from repro.models import transformer as tf

    cfg = tf.TransformerCfg(
        name="m", n_layers=1, d_model=32, n_heads=4, n_kv_heads=4, d_head=8,
        d_ff=32, vocab=64, moe=tf.MoECfg(n_experts=8, top_k=2, d_ff_expert=32,
                                         capacity_factor=8.0),  # no drops: exact match
    )
    params = tf.init(cfg, jax.random.PRNGKey(0))
    lp = jax.tree.map(lambda x: x[0], params["layers"])  # single layer slice
    rng = np.random.default_rng(0)
    B, S, D = 4, 8, 32
    x = jnp.asarray(rng.standard_normal((B, S, D)), jnp.bfloat16)

    ref = tf.moe_ffn(cfg, lp, x.reshape(B * S, D)).reshape(B, S, D)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with mesh:
        got = tf.moe_ffn_shmap(cfg, lp, x, mesh=mesh, dp_axes=("data",))
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref, np.float32), rtol=5e-2, atol=5e-2
    )
    # gradients flow through the shard_map path
    def loss(lp):
        with mesh:
            y = tf.moe_ffn_shmap(cfg, lp, x, mesh=mesh, dp_axes=("data",))
        return jnp.sum(y.astype(jnp.float32) ** 2)

    g = jax.grad(loss)(lp)
    assert float(jnp.abs(g["we1"]).sum()) > 0
    assert float(jnp.abs(g["router"]).sum()) > 0
    print("moe_shmap OK")


if __name__ == "__main__":
    case = sys.argv[1]
    {
        "engine": case_engine,
        "engine_pruned": case_engine_pruned,
        "compress": case_compress,
        "sortedset_union": case_sortedset_union,
        "moe_shmap": case_moe_shmap,
    }[case]()
