"""SP/OP predicate-index differential suite (the k²-triples+ subsystem).

210 randomized mini-stores — including empty subjects, single-predicate
stores, and all-preds-hit rows — are packed into ONE combined store with
disjoint subject/object/predicate ranges, so every logical store keeps its
own random structure while the whole suite shares one set of array shapes
(one compile per program).  The index-pruned unbounded path is asserted
bit-exact against the all-preds sweep AND the brute-force triple set, on
both scan backends.
"""

import numpy as np
import pytest

from repro.core import k2forest, k2triples, predindex
from repro.core import engine as eng

import jax.numpy as jnp

N_STORES = 210
SUB, OBJ, PRE = 12, 14, 6  # per-store dictionary extents


def _gen_combined(seed=0):
    """210 random mini-stores in disjoint id ranges -> one triple set."""
    rng = np.random.default_rng(seed)
    rows = []
    for i in range(N_STORES):
        s0, o0, p0 = i * SUB, i * OBJ, i * PRE
        kind = i % 7
        if kind == 0:
            continue  # fully empty store (all its subjects are empty)
        if kind == 1:  # single-predicate store
            n = int(rng.integers(1, 20))
            s = rng.integers(1, SUB + 1, n)
            p = np.full(n, 1)
            o = rng.integers(1, OBJ + 1, n)
        elif kind == 2:  # all-preds-hit: one subject uses every predicate
            s = np.full(PRE, 1 + int(rng.integers(0, SUB)))
            p = np.arange(1, PRE + 1)
            o = rng.integers(1, OBJ + 1, PRE)
        elif kind == 3:  # dense-ish
            n = int(rng.integers(40, 90))
            s = rng.integers(1, SUB + 1, n)
            p = rng.integers(1, PRE + 1, n)
            o = rng.integers(1, OBJ + 1, n)
        else:  # sparse random
            n = int(rng.integers(1, 25))
            s = rng.integers(1, SUB + 1, n)
            p = rng.integers(1, PRE + 1, n)
            o = rng.integers(1, OBJ + 1, n)
        rows.append(np.stack([s + s0, p + p0, o + o0], axis=1))
    ids = np.unique(np.concatenate(rows), axis=0)
    return ids


@pytest.fixture(scope="module")
def combined():
    ids = _gen_combined()
    store = k2triples.from_id_triples(
        ids, n_so=0, n_subjects=N_STORES * SUB, n_objects=N_STORES * OBJ,
        n_preds=N_STORES * PRE,
    )
    T = set(map(tuple, ids.tolist()))
    return store, T, ids


def test_index_build_matches_bruteforce(combined):
    store, T, ids = combined
    bi = store.pred_index
    sp = {}
    op = {}
    for (s, p, o) in T:
        sp.setdefault(s, set()).add(p - 1)
        op.setdefault(o, set()).add(p - 1)
    rng = np.random.default_rng(1)
    for s in rng.integers(1, store.n_subjects + 1, 300):
        s = int(s)
        assert bi.host_list(predindex.subject_row(s)).tolist() == sorted(
            sp.get(s, ())
        ), s
    for o in rng.integers(1, store.n_objects + 1, 300):
        o = int(o)
        assert bi.host_list(predindex.object_row(bi.meta, o)).tolist() == sorted(
            op.get(o, ())
        ), o
    # honest accounting: entries match the distinct-pair counts
    assert bi.stats.sp_entries == sum(len(v) for v in sp.values())
    assert bi.stats.op_entries == sum(len(v) for v in op.values())
    assert bi.stats.payload_bits > 0 and bi.stats.dac_bits > 0
    assert bi.stats.bits_per_triple > 0
    assert bi.meta.max_degree <= PRE


def _sample_keys(store, T, rng, n):
    """Mixed subject/object keys: hits, empties, and out-of-range-free ids."""
    subs = sorted({t[0] for t in T})
    objs = sorted({t[2] for t in T})
    keys, axes = [], []
    for i in range(n):
        if i % 4 == 0:  # an empty subject (store 0 mod 7 has none)
            keys.append(int(rng.integers(1, SUB + 1)))
            axes.append(0)
        elif i % 4 == 1:
            keys.append(int(subs[rng.integers(0, len(subs))]))
            axes.append(0)
        elif i % 4 == 2:
            keys.append(int(objs[rng.integers(0, len(objs))]))
            axes.append(1)
        else:
            keys.append(int(rng.integers(1, store.n_objects + 1)))
            axes.append(1)
    return np.array(keys, np.int64), np.array(axes, np.int32)


@pytest.mark.parametrize("layout", ["dac", "fixed"])
@pytest.mark.parametrize("backend", ["pallas", "jnp"])
def test_pruned_scan_vs_sweep_vs_truth(combined, backend, layout):
    """The acceptance gate: pruned == all-preds sweep == dense truth,
    identically under both on-device index layouts."""
    store, T, ids = combined
    bi = store.pred_index
    dev, pmeta = bi.select(layout)
    cap = 32
    rng = np.random.default_rng(2)
    keys, axes = _sample_keys(store, T, rng, 16)
    r = predindex.scan_pruned_batch(
        store.meta, store.forest, pmeta, dev, keys - 1, axes, cap,
        pmeta.max_degree, backend,
    )
    # the sweep reference: every predicate, broadcast keys, ONE launch
    P = store.n_preds
    preds_f = np.tile(np.arange(P, dtype=np.int32), len(keys))
    sweep = k2forest.scan_batch_mixed(
        store.meta, store.forest, preds_f, np.repeat(keys - 1, P),
        np.repeat(axes, P), cap, backend,
    )
    sw_ids = np.asarray(sweep.ids).reshape(len(keys), P, cap)
    sw_valid = np.asarray(sweep.valid).reshape(len(keys), P, cap)
    pr, pv = np.asarray(r.preds), np.asarray(r.pvalid)
    rid, rva = np.asarray(r.ids), np.asarray(r.valid)
    assert not np.asarray(r.truncated).any()
    for i in range(len(keys)):
        k_ = int(keys[i])
        cands = pr[i][pv[i]].tolist()
        # candidates cover exactly the predicates with any result
        truth_preds = sorted(
            {p - 1 for (s, p, o) in T if (s if axes[i] == 0 else o) == k_}
        )
        assert cands == truth_preds, i
        for p in range(P):
            exp_sweep = sw_ids[i, p][sw_valid[i, p]].tolist()
            if p in cands:
                l = int(np.nonzero(pv[i] & (pr[i] == p))[0][0])
                got = rid[i, l][rva[i, l]].tolist()
                assert got == exp_sweep, (i, p)  # bit-exact vs the sweep
                truth = sorted(
                    (o - 1 if axes[i] == 0 else s - 1)
                    for (s, pp, o) in T
                    if pp - 1 == p and (s if axes[i] == 0 else o) == k_
                )
                assert got == truth[: len(got)] and (
                    len(got) == len(truth) or cap < len(truth)
                ), (i, p)
            else:
                assert exp_sweep == [], (i, p)  # non-candidates are empty


@pytest.mark.parametrize("layout", ["dac", "fixed"])
@pytest.mark.parametrize("backend", ["pallas", "jnp"])
def test_pruned_check_vs_all_preds(combined, backend, layout):
    store, T, ids = combined
    bi = store.pred_index
    dev, pmeta = bi.select(layout)
    rng = np.random.default_rng(3)
    # pairs from real triples (hits guaranteed), plus misses
    picks = ids[rng.integers(0, ids.shape[0], 24)]
    s_arr = picks[:, 0].copy()
    o_arr = picks[:, 2].copy()
    o_arr[::3] = rng.integers(1, store.n_objects + 1, len(o_arr[::3]))  # misses
    r = predindex.check_pruned_batch(
        store.meta, store.forest, pmeta, dev, s_arr - 1, o_arr - 1,
        pmeta.max_degree, backend,
    )
    for i in range(len(s_arr)):
        allp = np.asarray(
            k2forest.check_all_preds(
                store.meta, store.forest, int(s_arr[i]) - 1, int(o_arr[i]) - 1
            )
        )
        exp = np.nonzero(allp)[0].tolist()
        got = np.asarray(r.ids[i])[np.asarray(r.valid[i])].tolist()
        assert got == exp, i
        truth = sorted(
            p - 1 for (s, p, o) in T if s == s_arr[i] and o == o_arr[i]
        )
        assert got == truth, i


@pytest.mark.parametrize("backend", ["pallas", "jnp"])
def test_unified_serve_pruned_equals_fallback(combined, backend):
    """One mixed six-op batch through the serve IR: the index-pruned program
    and the all-preds fallback decode to identical answers."""
    store, T, ids = combined
    bi = store.pred_index
    rng = np.random.default_rng(4)
    B = 24
    picks = ids[rng.integers(0, ids.shape[0], B)]
    ops = rng.integers(0, 6, B).astype(np.int32)
    q = eng.ServeBatch(
        op=jnp.asarray(ops),
        s=jnp.asarray(picks[:, 0], jnp.int32),
        p=jnp.asarray(np.where(ops >= 3, 0, picks[:, 1]), jnp.int32),
        o=jnp.asarray(picks[:, 2], jnp.int32),
    )
    cap = 32
    results = {}
    for layout in ("dac", "fixed"):
        dev, pmeta = bi.select(layout)
        pruned = eng.make_serve_step(
            store.meta, cap, backend=backend, pmeta=pmeta
        )
        results[layout] = pruned(store.forest, q, dev)
    fallback = eng.make_serve_step(
        store.meta, cap, backend=backend, u_width=store.n_preds
    )
    r1 = results["dac"]
    r2 = fallback(store.forest, q)
    # the two pruned layouts are bit-identical on EVERY output field
    for a, b in zip(results["dac"], results["fixed"]):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    hit1, hit2 = np.asarray(r1.hit), np.asarray(r2.hit)
    for i in range(B):
        assert hit1[i] == hit2[i], i
        if ops[i] in (1, 2, 5):
            a = np.asarray(r1.ids[i])[np.asarray(r1.valid[i])]
            b = np.asarray(r2.ids[i])[np.asarray(r2.valid[i])]
            assert a.tolist() == b.tolist(), i
        if ops[i] in (3, 4):
            d1 = {
                int(p): np.asarray(r1.u_ids[i, l])[np.asarray(r1.u_valid[i, l])].tolist()
                for l, p in enumerate(np.asarray(r1.u_preds[i]))
                if p and np.asarray(r1.u_valid[i, l]).any()
            }
            d2 = {
                int(p): np.asarray(r2.u_ids[i, l])[np.asarray(r2.u_valid[i, l])].tolist()
                for l, p in enumerate(np.asarray(r2.u_preds[i]))
                if p and np.asarray(r2.u_valid[i, l]).any()
            }
            assert d1 == d2, i
