"""The compressed dictionary layer: Elias–Fano access, bucketed plain
front coding (locate/extract inverses, bucket-boundary exactness), the
4-range :class:`CompressedTripleDictionary` vs the plain
:class:`TripleDictionary` oracle, and the measured-vs-analytic size
contract that keeps ``bench_compression``'s end-to-end column honest."""

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import k2triples
from repro.core.dictionary import (
    CompressedTripleDictionary,
    EliasFano,
    FrontCodedStrings,
    build_compressed_dictionary,
    build_dictionary,
)
from repro.data import rdf


# ---------------------------------------------------------------------------
# Elias–Fano
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=50), max_size=60))
def test_elias_fano_access_property(deltas):
    """EF[i] == values[i] for any non-decreasing sequence (built here as a
    cumsum of non-negative deltas, covering runs of equal values)."""
    values = np.cumsum(np.asarray(deltas, np.int64)).tolist()
    ef = EliasFano(values)
    assert len(ef) == len(values)
    assert [ef[i] for i in range(len(ef))] == values


def test_elias_fano_edges_and_validation():
    assert len(EliasFano([])) == 0
    ef1 = EliasFano([0])
    assert ef1[0] == 0
    with pytest.raises(IndexError):
        ef1[1]
    with pytest.raises(ValueError):
        EliasFano([3, 2])
    with pytest.raises(ValueError):
        EliasFano([-1, 2])
    # sparse universe: l > 0 and the low-bit plane is exercised
    big = [i * 977 for i in range(100)]
    ef = EliasFano(big)
    assert ef._l > 0
    assert [ef[i] for i in range(100)] == big
    # dense: l == 0, pure unary high bits
    dense = list(range(64))
    ef0 = EliasFano(dense)
    assert ef0._l == 0
    assert [ef0[i] for i in range(64)] == dense


def test_elias_fano_measured_vs_analytic():
    """Measured bits (words + rank blocks) stay within a small constant
    factor of the n*(2 + l) textbook bound on a realistic offset shape."""
    vals = np.cumsum(np.random.default_rng(0).integers(8, 64, 2000)).tolist()
    ef = EliasFano(vals)
    assert ef.analytic_bits() <= ef.size_bits() <= 3 * ef.analytic_bits() + 4 * 32
    # far below raw 32-bit storage
    assert ef.size_bits() < 32 * len(vals) / 2


# ---------------------------------------------------------------------------
# front-coded pool
# ---------------------------------------------------------------------------


def _uri_terms(n, seed=0):
    rng = np.random.default_rng(seed)
    terms = {f"http://ex.org/r/{int(i):07d}" for i in rng.integers(0, 10**7, n)}
    terms |= {f"urn:uuid:{int(i):04x}" for i in rng.integers(0, 16**4, n // 4)}
    return sorted(terms)


@pytest.mark.parametrize("bucket", [1, 3, 8])
def test_front_coding_extract_locate_inverse(bucket):
    """extract(locate(t)) == t and locate(extract(i)) == i for every term,
    at bucket sizes that land term counts on and off bucket boundaries."""
    terms = _uri_terms(400)
    fc = FrontCodedStrings(terms, bucket=bucket)
    assert len(fc) == len(terms)
    for i, t in enumerate(terms):
        assert fc[i] == t
        assert fc.locate(t) == i
    # misses: below the first head, above the last term, and near-hits
    assert fc.locate("") == -1
    assert fc.locate("zzzz") == -1
    assert fc.locate(terms[0] + "x") == -1
    assert fc.locate(terms[0][:-1]) == -1


def test_front_coding_exact_bucket_boundaries():
    """n a multiple of the bucket size: the final bucket is full, and the
    head of every bucket round-trips (head decoding is the locate hot
    path)."""
    bucket = 8
    terms = _uri_terms(1000)[: 12 * bucket]
    fc = FrontCodedStrings(terms, bucket=bucket)
    for b in range(12):
        assert fc[b * bucket] == terms[b * bucket]
        assert fc.locate(terms[b * bucket]) == b * bucket
    # and one past every boundary
    for b in range(12):
        assert fc[b * bucket + 1] == terms[b * bucket + 1]


def test_front_coding_unicode_and_empty():
    fc = FrontCodedStrings([], bucket=8)
    assert len(fc) == 0 and fc.locate("x") == -1
    terms = sorted({"", "a", "aé", "aé中", "béta", "中文"})
    fc = FrontCodedStrings(terms, bucket=2)
    for i, t in enumerate(terms):
        assert fc[i] == t and fc.locate(t) == i


def test_front_coding_measured_vs_analytic():
    """The size contract: measured bits (blob + EF incl. rank blocks) stay
    within 25% of the analytic figure, and well under raw UTF-8."""
    terms = _uri_terms(3000, seed=2)
    fc = FrontCodedStrings(terms, bucket=8)
    raw_bits = 8 * sum(len(t.encode()) for t in terms)
    assert fc.analytic_bits() <= fc.size_bits() <= 1.25 * fc.analytic_bits()
    assert fc.size_bits() < raw_bits / 2
    assert fc.size_bytes() == (fc.size_bits() + 7) // 8


# ---------------------------------------------------------------------------
# the 4-range compressed dictionary
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def string_corpus():
    return rdf.generate_strings(3000, like="geonames", seed=4)


def test_compressed_dictionary_matches_plain(string_corpus):
    """Differential vs the tuple-backed TripleDictionary: same ranges,
    same ids, same decodes, KeyError on the same unknowns."""
    strs = string_corpus
    cd = build_compressed_dictionary(strs)
    pd = build_dictionary(strs)
    assert (cd.n_so, cd.n_subjects, cd.n_objects, cd.n_preds) == (
        pd.n_so, pd.n_subjects, pd.n_objects, pd.n_preds,
    )
    assert cd.matrix_extent == pd.matrix_extent
    enc_c = cd.encode_triples(strs[:500])
    enc_p = pd.encode_triples(strs[:500])
    assert np.array_equal(enc_c, enc_p)
    for (s, p, o), (si, pi, oi) in zip(strs[:200], enc_c[:200]):
        assert cd.decode_subject(int(si)) == s
        assert cd.decode_predicate(int(pi)) == p
        assert cd.decode_object(int(oi)) == o
    for fn in (cd.encode_subject, cd.encode_object, cd.encode_predicate):
        with pytest.raises(KeyError):
            fn("http://nowhere/at/all")
    # the tuple-compat properties materialize the same term lists
    assert cd.so_terms == pd.so_terms
    assert cd.p_terms == pd.p_terms


def test_compressed_dictionary_size_contract(string_corpus):
    cd = build_compressed_dictionary(string_corpus)
    assert cd.analytic_bits() <= cd.size_bits() <= 1.25 * cd.analytic_bits()
    assert cd.size_bits() < cd.raw_bits() / 2


def test_store_string_path_uses_compressed_dictionary(string_corpus):
    """from_string_triples defaults to the compressed dictionary and the
    two dictionary flavors build IDENTICAL stores."""
    strs = string_corpus[:800]
    st_c = k2triples.from_string_triples(strs)
    st_p = k2triples.from_string_triples(strs, compressed=False)
    assert isinstance(st_c.dictionary, CompressedTripleDictionary)
    assert st_c.n_triples == st_p.n_triples
    assert np.array_equal(
        np.asarray(st_c.forest.t_words), np.asarray(st_p.forest.t_words)
    )
    bits_c = k2triples.size_dictionary_bits(st_c)
    bits_p = k2triples.size_dictionary_bits(st_p)
    assert 0 < bits_c < bits_p  # compressed beats raw UTF-8 accounting
