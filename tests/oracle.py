"""Shared numpy dense-matrix oracles for the k² differential test harness.

Ground truth for every traversal variant is the uncompressed boolean matrix:
a scan's full answer is one ``np.nonzero`` away.  The capped fixed-shape
``QueryResult`` contract then admits exactly one correct behavior, asserted
by ``assert_scan_result``:

  * every returned id is a true 1-cell (no false positives, ever);
  * results arrive ID-sorted and ``valid`` is a count-prefix mask;
  * ``overflow=False``  =>  the answer is complete and count is exact;
  * ``overflow=True``   =>  the returned ids are a PREFIX of the sorted
    truth (level-synchronous truncation keeps the lowest free-axis
    subtrees, whose ids all precede any dropped subtree's ids).
"""

from __future__ import annotations

import numpy as np


def dense_from_coords(coords, side: int) -> list[np.ndarray]:
    """One dense uint8 matrix per predicate from (rows, cols) lists."""
    out = []
    for rows, cols in coords:
        d = np.zeros((side, side), np.uint8)
        if len(rows):
            d[np.asarray(rows), np.asarray(cols)] = 1
        out.append(d)
    return out


def scan_truth(dense: np.ndarray, key: int, axis: int) -> np.ndarray:
    """Sorted ids of the 1-cells in row (axis=0) / column (axis=1) ``key``."""
    line = dense[key] if axis == 0 else dense[:, key]
    return np.nonzero(line)[0].astype(np.int32)


def assert_scan_result(ids, valid, count, overflow, truth: np.ndarray, cap: int,
                       label=""):
    """Check one capped scan result against the dense truth."""
    ids = np.asarray(ids)
    valid = np.asarray(valid)
    count = int(count)
    overflow = bool(overflow)
    assert count <= cap, f"{label}: count {count} > cap {cap}"
    assert count <= len(truth), f"{label}: count {count} > truth {len(truth)}"
    # valid is exactly the count-prefix mask; dead lanes are zeroed
    assert (valid == (np.arange(cap) < count)).all(), f"{label}: valid mask"
    assert (ids[~valid] == 0).all(), f"{label}: dead lanes not zeroed"
    # returned ids are a prefix of the sorted truth
    assert (ids[:count] == truth[:count]).all(), (
        f"{label}: ids {ids[:count]} != truth prefix {truth[:count]}"
    )
    if not overflow:
        assert count == len(truth), (
            f"{label}: no overflow but count {count} != |truth| {len(truth)}"
        )


def assert_results_identical(a, b, label=""):
    """Bit-exact agreement between two (ids, valid, count, overflow) tuples."""
    names = ("ids", "valid", "count", "overflow")
    for name, x, y in zip(names, a, b):
        x, y = np.asarray(x), np.asarray(y)
        assert x.shape == y.shape, f"{label}:{name} shape {x.shape} vs {y.shape}"
        same = x == y
        assert np.asarray(same).all(), (
            f"{label}:{name} differs at {np.transpose(np.nonzero(~same))[:5]}"
        )
