"""Shared numpy dense-matrix oracles for the k² differential test harness.

Ground truth for every traversal variant is the uncompressed boolean matrix:
a scan's full answer is one ``np.nonzero`` away.  The capped fixed-shape
``QueryResult`` contract then admits exactly one correct behavior, asserted
by ``assert_scan_result``:

  * every returned id is a true 1-cell (no false positives, ever);
  * results arrive ID-sorted and ``valid`` is a count-prefix mask;
  * ``overflow=False``  =>  the answer is complete and count is exact;
  * ``overflow=True``   =>  the returned ids are a PREFIX of the sorted
    truth (level-synchronous truncation keeps the lowest free-axis
    subtrees, whose ids all precede any dropped subtree's ids).
"""

from __future__ import annotations

import numpy as np


def dense_from_coords(coords, side: int) -> list[np.ndarray]:
    """One dense uint8 matrix per predicate from (rows, cols) lists."""
    out = []
    for rows, cols in coords:
        d = np.zeros((side, side), np.uint8)
        if len(rows):
            d[np.asarray(rows), np.asarray(cols)] = 1
        out.append(d)
    return out


def scan_truth(dense: np.ndarray, key: int, axis: int) -> np.ndarray:
    """Sorted ids of the 1-cells in row (axis=0) / column (axis=1) ``key``."""
    line = dense[key] if axis == 0 else dense[:, key]
    return np.nonzero(line)[0].astype(np.int32)


def assert_scan_result(ids, valid, count, overflow, truth: np.ndarray, cap: int,
                       label=""):
    """Check one capped scan result against the dense truth."""
    ids = np.asarray(ids)
    valid = np.asarray(valid)
    count = int(count)
    overflow = bool(overflow)
    assert count <= cap, f"{label}: count {count} > cap {cap}"
    assert count <= len(truth), f"{label}: count {count} > truth {len(truth)}"
    # valid is exactly the count-prefix mask; dead lanes are zeroed
    assert (valid == (np.arange(cap) < count)).all(), f"{label}: valid mask"
    assert (ids[~valid] == 0).all(), f"{label}: dead lanes not zeroed"
    # returned ids are a prefix of the sorted truth
    assert (ids[:count] == truth[:count]).all(), (
        f"{label}: ids {ids[:count]} != truth prefix {truth[:count]}"
    )
    if not overflow:
        assert count == len(truth), (
            f"{label}: no overflow but count {count} != |truth| {len(truth)}"
        )


def morton_pairs_truth(dense: np.ndarray, ks) -> tuple[np.ndarray, np.ndarray]:
    """All 1-cells of ``dense`` in the k²-tree's Morton (level-order) sequence.

    ``range_scan`` emits pairs in mixed-radix Morton order — the order the
    paper's DFS visits leaves — so the oracle sorts by the same code the
    host-side builder assigns.
    """
    rows, cols = np.nonzero(dense)
    r = rows.astype(np.int64)
    c = cols.astype(np.int64)
    code = np.zeros(r.shape[0], np.int64)
    s = int(np.prod(ks))
    for k in ks:
        s //= k
        code = code * (k * k) + (r // s) * k + (c // s)
        r %= s
        c %= s
    order = np.argsort(code)
    return rows[order].astype(np.int32), cols[order].astype(np.int32)


def assert_pair_result(rows, cols, valid, count, overflow,
                       truth_rows: np.ndarray, truth_cols: np.ndarray,
                       cap: int, label=""):
    """Check one capped range-scan (pair) result against the Morton truth."""
    rows = np.asarray(rows)
    cols = np.asarray(cols)
    valid = np.asarray(valid)
    count = int(count)
    overflow = bool(overflow)
    n_truth = len(truth_rows)
    assert count <= cap, f"{label}: count {count} > cap {cap}"
    assert count <= n_truth, f"{label}: count {count} > truth {n_truth}"
    assert (valid == (np.arange(cap) < count)).all(), f"{label}: valid mask"
    assert (rows[~valid] == 0).all() and (cols[~valid] == 0).all(), (
        f"{label}: dead lanes not zeroed"
    )
    # returned pairs are a prefix of the Morton-ordered truth: truncation
    # keeps the earliest subtrees, whose cells all precede any dropped ones
    assert (rows[:count] == truth_rows[:count]).all(), (
        f"{label}: rows {rows[:count]} != truth prefix {truth_rows[:count]}"
    )
    assert (cols[:count] == truth_cols[:count]).all(), (
        f"{label}: cols {cols[:count]} != truth prefix {truth_cols[:count]}"
    )
    if not overflow:
        assert count == n_truth, (
            f"{label}: no overflow but count {count} != |truth| {n_truth}"
        )


def assert_results_identical(a, b, label=""):
    """Bit-exact agreement between two result tuples (any field count)."""
    assert len(a) == len(b), f"{label}: arity {len(a)} vs {len(b)}"
    names = [f"field{i}" for i in range(len(a))]
    names[: min(len(a), 4)] = ("ids", "valid", "count", "overflow")[: len(a)]
    for name, x, y in zip(names, a, b):
        x, y = np.asarray(x), np.asarray(y)
        assert x.shape == y.shape, f"{label}:{name} shape {x.shape} vs {y.shape}"
        same = x == y
        assert np.asarray(same).all(), (
            f"{label}:{name} differs at {np.transpose(np.nonzero(~same))[:5]}"
        )
