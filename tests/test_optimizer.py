"""BGP optimizer (the paper's future-work item) vs a brute-force oracle."""

import itertools

import numpy as np
import pytest

from repro.core import k2triples
from repro.core.optimizer import TriplePattern, estimate_cardinality, execute_bgp, plan
from repro.data import rdf


@pytest.fixture(scope="module")
def store_T():
    ds = rdf.generate(2500, n_subjects=90, n_preds=6, n_objects=110, seed=5)
    store = k2triples.from_id_triples(
        ds.ids, n_so=ds.n_so, n_subjects=ds.n_subjects,
        n_objects=ds.n_objects, n_preds=ds.n_preds,
    )
    return store, set(map(tuple, ds.ids.tolist())), ds


def _oracle_bgp(T, patterns):
    """Brute-force: enumerate all variable assignments consistent with T."""
    sols = [dict()]
    for pat in patterns:
        new = []
        for b in sols:
            for (s, p, o) in T:
                bb = dict(b)
                ok = True
                for term, val in ((pat.s, s), (pat.p, p), (pat.o, o)):
                    if isinstance(term, str):
                        if term in bb and bb[term] != val:
                            ok = False
                            break
                        bb[term] = val
                    elif term != val:
                        ok = False
                        break
                if ok:
                    new.append(bb)
        sols = new
    keys = sorted({k for s in sols for k in s})
    return {tuple(s[k] for k in keys) for s in sols}, keys


def _got_set(bindings):
    keys = sorted(bindings)
    if not keys:
        return set(), []
    arr = np.stack([bindings[k] for k in keys], axis=1)
    return set(map(tuple, arr.tolist())), keys


def test_cardinality_ordering(store_T):
    store, T, ds = store_T
    s, p, o = map(int, ds.ids[0])
    # strictly more selective patterns estimate lower
    c_spo = estimate_cardinality(store, TriplePattern(s, p, o))
    c_sp = estimate_cardinality(store, TriplePattern(s, p, "?o"))
    c_p = estimate_cardinality(store, TriplePattern("?s", p, "?o"))
    c_any = estimate_cardinality(store, TriplePattern("?s", "?p", "?o"))
    assert c_spo <= c_sp <= c_p <= c_any


def test_plan_starts_selective(store_T):
    store, T, ds = store_T
    s, p, o = map(int, ds.ids[0])
    pats = [
        TriplePattern("?x", "?p", "?y"),  # huge
        TriplePattern(s, p, "?x"),  # selective
    ]
    assert plan(store, pats)[0] == 1


def test_two_pattern_chain_matches_oracle(store_T):
    store, T, ds = store_T
    # pick a triple whose object is also a subject (chain exists)
    subs = {t[0] for t in T}
    seed = next(t for t in T if t[2] in subs)
    s, p, o = seed
    pats = [TriplePattern(s, p, "?x"), TriplePattern("?x", "?q", "?y")]
    got, keys = _got_set(execute_bgp(store, pats))
    exp, ekeys = _oracle_bgp(T, pats)
    assert keys == ekeys
    assert got == exp


def test_three_pattern_star_matches_oracle(store_T):
    store, T, ds = store_T
    s, p, o = map(int, ds.ids[7])
    pats = [
        TriplePattern(s, "?p1", "?x"),
        TriplePattern(s, p, "?y"),
        TriplePattern("?z", "?p2", "?x"),
    ]
    got, keys = _got_set(execute_bgp(store, pats))
    exp, ekeys = _oracle_bgp(T, pats)
    assert keys == ekeys
    assert got == exp


def test_empty_result(store_T):
    store, T, ds = store_T
    pats = [TriplePattern(ds.n_subjects, 1, "?x"), TriplePattern("?x", 1, "?y")]
    got = execute_bgp(store, pats)
    if got:
        assert all(len(v) == 0 for v in got.values()) or _oracle_bgp(T, pats)[0] == _got_set(got)[0]
