"""The cost-based planner: deterministic tie-breaks, DP-vs-greedy
divergence on a crafted greedy trap, SelectQ end-to-end through
``Engine.compile``, and planner observability (``planner.order`` span +
SIP-pruning counter, with the disabled-path tripwire)."""

import numpy as np
import pytest

import repro.obs as obs
from repro.core import algebra, engine as eng, k2triples, optimizer, planner
from repro.core.algebra import Cmp, TriplePattern
from repro.core.query import ExecConfig, ObsConfig, SelectQ, TriplePatternQ
from repro.data import rdf
from repro.obs.metrics import Counter, Gauge, Histogram
from repro.obs.trace import Tracer


@pytest.fixture(autouse=True)
def _obs_off_after():
    yield
    obs.disable()


def _store_from_triples(ids, *, n_subjects, n_objects, n_preds):
    ids = np.asarray(ids, np.int64)
    return k2triples.from_id_triples(
        ids, n_so=min(n_subjects, n_objects), n_subjects=n_subjects,
        n_objects=n_objects, n_preds=n_preds,
    )


@pytest.fixture(scope="module")
def rdf_store():
    ds = rdf.generate(220, n_subjects=16, n_preds=5, n_objects=18, seed=17)
    store = k2triples.from_id_triples(
        ds.ids, n_so=ds.n_so, n_subjects=ds.n_subjects,
        n_objects=ds.n_objects, n_preds=ds.n_preds,
    )
    return store, list(map(tuple, ds.ids.tolist())), ds


# ---------------------------------------------------------------------------
# deterministic planning
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def symmetric_store():
    """n_subjects == n_objects and one predicate: a chain of identical
    patterns prices the same in every direction — a pure tie."""
    ids = [(s, 1, (s % 16) + 1) for s in range(1, 17)]
    return _store_from_triples(ids, n_subjects=16, n_objects=16, n_preds=1)


def test_tie_breaks_by_lowest_pattern_index(symmetric_store):
    chain = [
        TriplePattern("?a", 1, "?b"),
        TriplePattern("?b", 1, "?c"),
        TriplePattern("?c", 1, "?d"),
    ]
    ests = [planner.estimate_cardinality(symmetric_store, p) for p in chain]
    assert ests[0] == ests[1] == ests[2]  # genuinely tied
    # [0,1,2] and [2,1,0] cost the same; index breaks the tie
    fwd = planner.order_cost(symmetric_store, chain, [0, 1, 2])
    rev = planner.order_cost(symmetric_store, chain, [2, 1, 0])
    assert fwd == pytest.approx(rev)
    assert planner.greedy_order(symmetric_store, chain) == [0, 1, 2]
    assert planner.cost_order(symmetric_store, chain) == [0, 1, 2]
    # the optimizer facade delegates, and repeated calls are stable
    assert optimizer.plan(symmetric_store, chain) == [0, 1, 2]
    assert all(
        planner.cost_order(symmetric_store, chain) == [0, 1, 2]
        for _ in range(3)
    )


@pytest.fixture(scope="module")
def trap_store():
    """A greedy trap: the anchor binds a tiny-extent variable (?s, 4
    subjects) and a huge-extent one (?x, 1000 objects).  Greedy's flat
    connected-bonus (÷10) prefers the smaller stand-alone pattern even
    though its shared variable barely prunes; the DP prices the join
    through the per-variable extents and flips the order."""
    ids = []
    ids += [(s, 1, 10 * s) for s in range(1, 5)]                # nnz(p1)=4
    ids += [((i % 4) + 1, 2, 100 + i) for i in range(30)]       # nnz(p2)=30
    ids += [(1, 3, 10), (2, 3, 20)]                             # join the ?x chain
    ids += [((i % 4) + 1, 3, 500 + i) for i in range(48)]       # nnz(p3)=50
    return _store_from_triples(ids, n_subjects=4, n_objects=1000, n_preds=3)


def test_dp_beats_greedy_on_trap(trap_store):
    pats = [
        TriplePattern("?s", 1, "?x"),   # anchor: est 4
        TriplePattern("?s", 2, "?z"),   # est 30, shares ?s (extent 4)
        TriplePattern("?w", 3, "?x"),   # est 50, shares ?x (extent 1000)
    ]
    g = planner.greedy_order(trap_store, pats)
    c = planner.cost_order(trap_store, pats)
    assert g == [0, 1, 2]  # greedy: smaller stand-alone estimate first
    assert c == [0, 2, 1]  # DP: the ?x join prunes ~250x harder
    assert (
        planner.order_cost(trap_store, pats, c)
        < planner.order_cost(trap_store, pats, g)
    )
    # identical answers either way (same machinery, different order)
    a = planner.execute(trap_store, algebra.bgp(pats), cap=512, exec_="jnp")
    b = planner.execute(
        trap_store, algebra.bgp(pats), cap=512, exec_="jnp",
        order_override=g,
    )
    key = sorted(a.cols)
    rows = lambda t: set(map(tuple, np.stack(
        [t.cols[k] for k in key], axis=1).tolist()))
    assert rows(a) == rows(b) and a.n > 0


@pytest.fixture(scope="module")
def pricing_store():
    """Crafted nnz profile for the lane-pricing flip: p1 holds 8 pairs,
    p2 holds 60 pairs over 10 subjects x 10 objects."""
    ids = [(s, 1, s) for s in range(1, 9)]
    ids += [(s, 2, o) for s in range(1, 11) for o in range(1, 7)]
    return _store_from_triples(ids, n_subjects=10, n_objects=10, n_preds=2)


def test_lane_pricing_flips_order(pricing_store):
    """Uniform lane pricing picks the WRONG order here: pattern B
    ((?x, 2, o)) is the more selective stand-alone scan, but once ?x is
    bound B becomes a check-shaped step — cheap per lane — so running
    the bigger scan A first and sweeping B as 8 check lanes is cheaper
    than scanning B first and expanding A over its 6 rows."""
    A = TriplePattern("?x", 1, "?y")
    B = TriplePattern("?x", 2, 3)
    pats = [A, B]
    # lane classification: B is a check once ?x carries values, A never is
    assert planner.step_lane_price(B, {"?x"}) == planner.LANE_PRICE_CHECK
    assert planner.step_lane_price(B, set()) == planner.LANE_PRICE_SCAN
    assert planner.step_lane_price(A, {"?x"}) == planner.LANE_PRICE_SCAN
    # ?p-free check shapes price as checks too (the OP_CHECK branch)
    assert (
        planner.step_lane_price(TriplePattern(4, "?p", 3), set())
        == planner.LANE_PRICE_CHECK
    )
    priced = planner.cost_order(pricing_store, pats)
    uniform = planner.cost_order(pricing_store, pats, lane_pricing=False)
    assert priced == [0, 1] and uniform == [1, 0]
    # each search minimizes ITS OWN objective...
    assert planner.order_cost(pricing_store, pats, priced) < planner.order_cost(
        pricing_store, pats, uniform
    )
    assert planner.order_cost(
        pricing_store, pats, uniform, lane_pricing=False
    ) < planner.order_cost(pricing_store, pats, priced, lane_pricing=False)
    # ...and both orders compute identical answers on identical machinery
    a = planner.execute(pricing_store, algebra.bgp(pats), cap=256, exec_="jnp")
    b = planner.execute(
        pricing_store, algebra.bgp(pats), cap=256, exec_="jnp",
        order_override=uniform,
    )
    key = sorted(a.cols)
    rows = lambda t: set(
        map(tuple, np.stack([t.cols[k] for k in key], axis=1).tolist())
    )
    assert rows(a) == rows(b) and a.n > 0


def test_cost_order_never_worse_than_greedy(rdf_store):
    """Model-level dominance: on random pattern sets the DP's modelled
    cost is <= greedy's (it searches a superset of greedy's orders)."""
    store, T, ds = rdf_store
    rng = np.random.default_rng(5)
    pool = ["?a", "?b", "?c"]
    for _ in range(20):
        pats = []
        for _ in range(int(rng.integers(2, 5))):
            terms = []
            for extent in (ds.n_subjects, ds.n_preds, ds.n_objects):
                r = rng.random()
                terms.append(
                    pool[rng.integers(0, 3)] if r < 0.5
                    else int(rng.integers(1, extent + 1))
                )
            pats.append(TriplePattern(*terms))
        if not any(p.variables for p in pats):
            continue
        g = planner.order_cost(store, pats, planner.greedy_order(store, pats))
        c = planner.order_cost(store, pats, planner.cost_order(store, pats))
        assert c <= g * (1 + 1e-9), (pats, c, g)


def test_dp_limit_falls_back_to_greedy(rdf_store):
    store, _, _ = rdf_store
    pats = [TriplePattern(f"?v{i}", 1, f"?v{i + 1}") for i in range(9)]
    assert len(pats) > planner.DP_LIMIT
    assert planner.cost_order(store, pats) == planner.greedy_order(store, pats)


# ---------------------------------------------------------------------------
# SelectQ end-to-end through Engine.compile
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["pallas", "jnp"])
def test_selectq_roundtrip(rdf_store, backend):
    store, T, ds = rdf_store
    E = eng.Engine(store)
    cfg = ExecConfig(backend=backend, cap=4096)

    q = SelectQ(
        where=(TriplePatternQ("?a", 1, "?b"),),
        optional=((TriplePatternQ("?b", 2, "?c"),),),
        filter=(Cmp(">", "?a", 3),),
        order_by=("-?b",),
        limit=7,
    )
    got = E.compile(q, cfg)()
    # oracle: compat left-join + 3VL filter + total-order slice
    left = [(s, o) for s, p, o in T if p == 1 and s > 3]
    rows = []
    for a, b in left:
        ms = [(a, b, o2) for s2, p2, o2 in T if p2 == 2 and s2 == b]
        rows.extend(ms if ms else [(a, b, 0)])
    uniq = sorted(set(rows), key=lambda r: (-r[1], r[0], r[2]))[:7]
    got_rows = list(zip(
        got["?a"].tolist(), got["?b"].tolist(), got["?c"].tolist(),
    ))
    assert got_rows == uniq

    # UNION with projection
    q2 = SelectQ(
        union=(
            (TriplePatternQ("?x", 1, "?y"),),
            (TriplePatternQ("?x", 2, "?y"),),
        ),
        select=("?x", "?y"),
    )
    got2 = E.compile(q2, cfg)()
    exp2 = {(s, o) for s, p, o in T if p in (1, 2)}
    assert set(zip(got2["?x"].tolist(), got2["?y"].tolist())) == exp2


def test_selectq_validation(rdf_store):
    store, _, _ = rdf_store
    E = eng.Engine(store)
    cfg = ExecConfig(backend="jnp", cap=256)
    with pytest.raises(ValueError):  # needs WHERE or UNION
        SelectQ()
    with pytest.raises(ValueError):  # order_by entries are '?v' / '-?v'
        SelectQ(where=(TriplePatternQ("?a", 1, "?b"),), order_by=("b",))
    with pytest.raises(ValueError):
        SelectQ(where=(TriplePatternQ("?a", 1, "?b"),), limit=-1)
    with pytest.raises(ValueError):
        SelectQ(where=(TriplePatternQ("?a", 1, "?b"),), offset=-1)
    with pytest.raises(ValueError, match="reserved"):
        E.compile(SelectQ(where=(TriplePatternQ("?__x", 1, "?b"),)), cfg)
    with pytest.raises(ValueError, match="name at least one"):
        E.compile(SelectQ(where=(TriplePatternQ(1, 1, 2),)), cfg)
    with pytest.raises(TypeError):  # filters must be algebra expressions
        E.compile(
            SelectQ(
                where=(TriplePatternQ("?a", 1, "?b"),), filter=("?a > 3",)
            ),
            cfg,
        )
    plan = E.compile(SelectQ(where=(TriplePatternQ("?a", 1, "?b"),)), cfg)
    with pytest.raises(ValueError, match="no batch"):
        plan(np.zeros(4))


def test_selectq_plan_cache_key(rdf_store):
    """All SELECTs share one shape key: recompiling a different SELECT
    under the same config is a plan-cache hit, not a recompile."""
    store, _, _ = rdf_store
    E = eng.Engine(store)
    cfg = ExecConfig(backend="jnp", cap=256)
    E.compile(SelectQ(where=(TriplePatternQ("?a", 1, "?b"),)), cfg)
    misses0 = E.plan_cache_stats["misses"]
    E.compile(SelectQ(where=(TriplePatternQ("?x", 2, "?y"),), limit=3), cfg)
    assert E.plan_cache_stats["misses"] == misses0
    assert E.plan_cache_stats["hits"] >= 1


# ---------------------------------------------------------------------------
# observability: span + counter when on, silence when off
# ---------------------------------------------------------------------------


def test_planner_order_span_and_sip_counter(rdf_store):
    store, T, ds = rdf_store
    assert store.pred_index is not None
    tracer, metrics = obs.enable(ObsConfig())
    # bound-s unbounded-?p step: the SP index prunes candidate lanes
    tree = algebra.bgp([
        TriplePattern("?a", 1, "?b"),
        TriplePattern("?b", "?p", "?c"),
    ])
    t = planner.execute(store, tree, cap=4096, exec_="jnp")
    assert t.n > 0
    spans = [e for e in tracer.events() if e["name"] == "planner.order"]
    assert spans, "planner must emit a planner.order span when tracing"
    args = spans[-1]["args"]
    assert args["patterns"] == 2 and len(args["order"]) == 2
    assert len(args["estimated"]) == len(args["actual"]) == 2
    assert args["actual"][-1] == t.n  # last step cardinality = result rows
    snap = metrics.snapshot()
    assert snap["planner.sip_pruned_lanes"]["value"] > 0


def test_planner_obs_disabled_is_free(monkeypatch, rdf_store):
    """With observability off, planner execution touches no obs surface
    — every recording call armed to raise, including ``Counter.inc``
    (the planner's counter is obs-layer metrics, not broker
    bookkeeping)."""
    store, _, _ = rdf_store
    tree = algebra.bgp([
        TriplePattern("?a", 1, "?b"),
        TriplePattern("?b", "?p", "?c"),
    ])
    planner.execute(store, tree, cap=4096, exec_="jnp")  # prime compiles

    def boom(name):
        def _(*a, **k):
            raise AssertionError(f"obs call {name} on the DISABLED path")
        return _

    for m in ("__init__", "begin", "end", "span", "add", "add_async",
              "instant", "_record"):
        monkeypatch.setattr(Tracer, m, boom(f"Tracer.{m}"))
    monkeypatch.setattr(Histogram, "observe", boom("Histogram.observe"))
    monkeypatch.setattr(Gauge, "set", boom("Gauge.set"))
    monkeypatch.setattr(Counter, "inc", boom("Counter.inc"))

    assert not obs.enabled()
    t = planner.execute(store, tree, cap=4096, exec_="jnp")
    assert t.n > 0
