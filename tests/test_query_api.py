"""The compiled-plan query API: ``Query -> Engine.compile(ExecConfig) -> Plan``.

Covers the redesign contracts:

  * ``ExecConfig`` is frozen + hashable and keys the plan cache;
  * plan-cache hit/miss semantics (same shape = hit, new shape/config =
    miss; plans of one cache slot share growth state);
  * cap-overflow recovery: the CapPolicy doubling loop equals a
    brute-force oracle on an overflow-inducing store, on BOTH backends;
  * quantile-sized unbounded lanes route degree outliers to the sweep
    fallback and stay exact;
  * the deprecation shims (``Engine.pattern`` / ``Engine.join`` /
    ``optimizer.execute_bgp``) warn and return identical results.
"""

import warnings

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import engine as eng, k2triples, optimizer
from repro.core.query import (
    AdmissionError, BgpQ, CapOverflow, CapPolicy, ExecConfig, JoinQ, Plan,
    ServeQ, TriplePatternQ, shape_key,
)
from repro.data import rdf


@pytest.fixture(scope="module")
def store_and_truth():
    ds = rdf.generate(
        2500, n_subjects=50, n_preds=12, n_objects=70,
        preds_per_subject=3, seed=17,
    )
    store = k2triples.from_id_triples(
        ds.ids, n_so=ds.n_so, n_subjects=ds.n_subjects,
        n_objects=ds.n_objects, n_preds=ds.n_preds,
    )
    return store, set(map(tuple, ds.ids.tolist())), ds


# ---------------------------------------------------------------------------
# ExecConfig
# ---------------------------------------------------------------------------


def test_exec_config_hashable_and_frozen():
    import dataclasses

    a = ExecConfig()
    b = ExecConfig()
    assert a == b and hash(a) == hash(b)
    c = a.replace(cap=128)
    assert c != a
    d = {a: 1, c: 2}  # usable as a cache key directly
    assert d[ExecConfig()] == 1
    with pytest.raises(dataclasses.FrozenInstanceError):
        a.cap = 5
    # nested CapPolicy participates in equality/hash
    assert a.replace(cap_policy=CapPolicy(grow=False)) != a


def test_exec_config_validation():
    with pytest.raises(ValueError):
        ExecConfig(backend="bogus")
    with pytest.raises(ValueError):
        ExecConfig(u_width_quantile=0.0)
    with pytest.raises(ValueError):
        ExecConfig(cap=0)


def test_exec_config_from_env(monkeypatch):
    monkeypatch.setenv("REPRO_SCAN_BACKEND", "jnp")
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    cfg = ExecConfig.from_env(cap=99)
    assert cfg.backend == "jnp" and cfg.interpret is False and cfg.cap == 99
    # the snapshot is one-time: flipping the env does NOT change the config
    monkeypatch.setenv("REPRO_SCAN_BACKEND", "pallas")
    assert cfg.backend == "jnp"
    assert cfg.resolved() is cfg  # interpret already concrete


def test_query_shapes_and_validation():
    assert shape_key(TriplePatternQ(1, 2, "?o")) == shape_key(
        TriplePatternQ(7, 9, None)
    )
    assert shape_key(TriplePatternQ(1, 2, "?o")) != shape_key(
        TriplePatternQ(1, "?p", 2)
    )
    with pytest.raises(ValueError):
        JoinQ("Z", "s", "s")
    with pytest.raises(ValueError):
        JoinQ("A", "s", "s", p1=1, c1=1, p2=1)  # missing c2
    with pytest.raises(ValueError):
        JoinQ("A", "x", "s", p1=1, c1=1, p2=1, c2=1)


# ---------------------------------------------------------------------------
# plan cache
# ---------------------------------------------------------------------------


def test_plan_cache_hit_miss(store_and_truth):
    store, T, ds = store_and_truth
    E = eng.Engine(store)
    cfg = ExecConfig(backend="jnp", cap=256)
    s1, p1, o1 = map(int, ds.ids[0])
    s2, p2, o2 = map(int, ds.ids[1])

    plan1 = E.compile(TriplePatternQ(s1, p1, "?o"), cfg)
    assert E.plan_cache_stats == {
        "hits": 0, "misses": 1, "denied": 0, "size": 1
    }
    # same shape, different constants -> HIT (constants are runtime inputs)
    plan2 = E.compile(TriplePatternQ(s2, p2, "?o"), cfg)
    assert E.plan_cache_stats["hits"] == 1
    assert plan1._executor is plan2._executor
    # different shape -> MISS
    E.compile(TriplePatternQ("?s", p1, o1), cfg)
    assert E.plan_cache_stats["misses"] == 2
    # different config -> MISS
    E.compile(TriplePatternQ(s1, p1, "?o"), cfg.replace(cap=512))
    assert E.plan_cache_stats["misses"] == 3
    # both plans answer correctly through the shared executor
    assert plan1().tolist() == sorted(
        oo for (ss, pp, oo) in T if ss == s1 and pp == p1
    )
    assert plan2().tolist() == sorted(
        oo for (ss, pp, oo) in T if ss == s2 and pp == p2
    )


def test_plan_cache_stats_admission_denied(store_and_truth):
    """Denied admission counts as ``denied`` — never as a miss, never as
    a cache entry — and does not poison later compiles of that shape."""
    store, T, ds = store_and_truth
    E = eng.Engine(store)
    cfg = ExecConfig(backend="jnp", cap=256)
    q = TriplePatternQ(int(ds.ids[0][0]), int(ds.ids[0][1]), "?o")

    with pytest.raises(AdmissionError):
        E.compile(q, cfg, admit=lambda key: False)
    assert E.plan_cache_stats == {
        "hits": 0, "misses": 0, "denied": 1, "size": 0
    }

    # the same shape compiles fine afterwards: a real miss, one entry
    plan = E.compile(q, cfg, admit=lambda key: True)
    assert E.plan_cache_stats == {
        "hits": 0, "misses": 1, "denied": 1, "size": 1
    }
    # hits never consult the admission hook at all
    boom = lambda key: (_ for _ in ()).throw(AssertionError("admit on hit"))
    plan2 = E.compile(q, cfg, admit=boom)
    assert plan2._executor is plan._executor
    assert E.plan_cache_stats == {
        "hits": 1, "misses": 1, "denied": 1, "size": 1
    }


def test_plan_batched_execution(store_and_truth):
    store, T, ds = store_and_truth
    E = eng.Engine(store)
    cfg = ExecConfig(backend="jnp", cap=256)
    plan = E.compile(TriplePatternQ(1, 1, "?o"), cfg)
    ids = ds.ids[:10]
    outs = plan({"s": ids[:, 0], "p": ids[:, 1]})
    assert len(outs) == 10
    for i, out in enumerate(outs):
        s_, p_ = int(ids[i, 0]), int(ids[i, 1])
        assert out.tolist() == sorted(
            oo for (ss, pp, oo) in T if ss == s_ and pp == p_
        )
    with pytest.raises(ValueError):
        plan({"o": ids[:, 2]})  # o is not a bound position of this shape


def test_repeated_variable_rejected_outside_bgp(store_and_truth):
    store, _, _ = store_and_truth
    E = eng.Engine(store)
    with pytest.raises(ValueError):
        E.compile(TriplePatternQ(1, "?x", "?x"))


# ---------------------------------------------------------------------------
# cap-overflow growth
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["pallas", "jnp"])
def test_cap_growth_matches_oracle(store_and_truth, backend):
    """cap=2 forces overflow on nearly every scan; the doubling policy must
    recover the complete brute-force answer, and grow=False must raise."""
    store, T, ds = store_and_truth
    E = eng.Engine(store)
    cfg = ExecConfig(
        backend=backend, cap=2, cap_policy=CapPolicy(grow=True, max_doublings=12)
    )
    rng = np.random.default_rng(5)
    for i in rng.integers(0, ds.n_triples, 4):
        s_, p_, o_ = map(int, ds.ids[i])
        plan = E.compile(TriplePatternQ(s_, p_, "?o"), cfg)
        assert plan().tolist() == sorted(
            oo for (ss, pp, oo) in T if ss == s_ and pp == p_
        )
        got = E.compile(TriplePatternQ(s_, None, None), cfg)()
        exp = {}
        for (ss, pp, oo) in T:
            if ss == s_:
                exp.setdefault(pp, []).append(oo)
        assert {k: v.tolist() for k, v in got.items()} == {
            k: sorted(v) for k, v in exp.items()
        }
    # a grown executor remembers its cap (> the configured 2)
    assert E.compile(TriplePatternQ(1, 1, "?o"), cfg).effective_cap > 2

    ungrown = ExecConfig(
        backend=backend, cap=2, cap_policy=CapPolicy(grow=False)
    )
    from collections import Counter

    (s_, p_), _ = Counter((s, p) for s, p, o in T).most_common(1)[0]
    with pytest.raises(CapOverflow):
        E.compile(TriplePatternQ(s_, p_, "?o"), ungrown)()


@pytest.mark.parametrize("backend", ["pallas", "jnp"])
def test_bgp_cap_growth_matches_oracle(store_and_truth, backend):
    store, T, ds = store_and_truth
    E = eng.Engine(store)
    cfg = ExecConfig(
        backend=backend, cap=4, cap_policy=CapPolicy(grow=True, max_doublings=12)
    )
    p_ = int(ds.ids[2][1])
    q = BgpQ((
        TriplePatternQ("?s", p_, "?o"),
        TriplePatternQ("?o", "?p2", "?z"),
    ))
    got = E.compile(q, cfg)()
    exp = {
        (s, o, p2, z)
        for (s, pp, o) in T
        if pp == p_
        for (s2, p2, z) in T
        if s2 == o
    }
    rows = {
        tuple(int(got[k][i]) for k in ("?s", "?o", "?p2", "?z"))
        for i in range(len(got["?s"]))
    }
    assert rows == exp


def test_bgp_anonymous_positions_projected(store_and_truth):
    """``None`` positions are existential: internal placeholder names never
    leak into the result, and the named columns are distinct rows."""
    store, T, ds = store_and_truth
    E = eng.Engine(store)
    cfg = ExecConfig(backend="jnp", cap=512)
    p_ = int(ds.ids[8][1])
    got = E.compile(BgpQ((TriplePatternQ("?s", p_, None),)), cfg)()
    assert set(got) == {"?s"}  # no ?__anon* keys
    exp = sorted({s for (s, pp, o) in T if pp == p_})
    assert sorted(got["?s"].tolist()) == exp  # distinct, no duplicates
    # all-anonymous BGPs have no projectable columns -> explicit error
    with pytest.raises(ValueError):
        E.compile(BgpQ((TriplePatternQ(1, None, None),)), cfg)
    # the internal prefix is reserved
    with pytest.raises(ValueError):
        E.compile(BgpQ((TriplePatternQ("?__anon0s", p_, None),)), cfg)


# ---------------------------------------------------------------------------
# quantile-sized unbounded lanes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["pallas", "jnp"])
def test_u_width_quantile_exact_with_outlier_fallback(store_and_truth, backend):
    store, T, ds = store_and_truth
    E = eng.Engine(store)
    exact = ExecConfig(backend=backend, cap=512)
    quant = exact.replace(u_width_quantile=0.5)
    # the quantile width must actually prune vs the hub-driven max
    assert E._u_width(quant) < E._u_width(exact)
    rng = np.random.default_rng(9)
    for i in rng.integers(0, ds.n_triples, 6):
        s_, _, o_ = map(int, ds.ids[i])
        for q in (TriplePatternQ(s_, None, None), TriplePatternQ(None, None, o_),
                  TriplePatternQ(s_, None, o_)):
            a = E.compile(q, exact)()
            b = E.compile(q, quant)()
            if isinstance(a, dict):
                assert {k: v.tolist() for k, v in a.items()} == {
                    k: v.tolist() for k, v in b.items()
                }
            else:
                assert a.tolist() == b.tolist()


def test_serveq_rejects_quantile(store_and_truth):
    store, _, _ = store_and_truth
    E = eng.Engine(store)
    with pytest.raises(ValueError):
        E.compile(ServeQ(), ExecConfig(u_width_quantile=0.5))


def test_mesh_rejected_for_unsharded_shapes(store_and_truth):
    """A mesh request must error, not silently run single-device, on the
    shapes that have no sharded program (pair/dump, joins D-F, BGP)."""
    import jax

    store, _, _ = store_and_truth
    E = eng.Engine(store)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = ExecConfig(mesh=mesh)
    with pytest.raises(ValueError):
        E.compile(TriplePatternQ("?s", 1, "?o"), cfg)  # pair enumeration
    with pytest.raises(ValueError):
        E.compile(TriplePatternQ(), cfg)  # dump
    with pytest.raises(ValueError):
        E.compile(JoinQ("D", "s", "o", p1=1, c1=1, p2=1), cfg)
    with pytest.raises(ValueError):
        E.compile(BgpQ((TriplePatternQ(1, "?p", "?o"),)), cfg)
    with pytest.raises(ValueError):
        plan = E.compile(TriplePatternQ(1, 1, "?o"), ExecConfig())
        plan({})  # empty batch is a misuse, not a crash


# ---------------------------------------------------------------------------
# ServeQ raw passthrough
# ---------------------------------------------------------------------------


def test_serveq_matches_reference(store_and_truth):
    store, T, ds = store_and_truth
    E = eng.Engine(store)
    rng = np.random.default_rng(3)
    B = 32
    ops = rng.integers(0, 6, B).astype(np.int32)
    ids = ds.ids[rng.integers(0, ds.n_triples, B)]
    q = eng.ServeBatch(
        op=jnp.asarray(ops),
        s=jnp.asarray(ids[:, 0], jnp.int32),
        p=jnp.asarray(np.where(ops >= 3, 0, ids[:, 1]), jnp.int32),
        o=jnp.asarray(ids[:, 2], jnp.int32),
    )
    cfg = ExecConfig(backend="jnp", cap=256)
    r = E.compile(ServeQ(), cfg)(q)
    bi = store.pred_index
    ref = eng.make_serve_step(store.meta, cap=256, backend=cfg, pmeta=bi.meta)(
        store.forest, q, bi.device
    )
    for name, a, b in zip(r._fields, r, ref):
        assert (np.asarray(a) == np.asarray(b)).all(), name
    with pytest.raises(ValueError):
        E.compile(ServeQ(), cfg)()  # a ServeQ plan needs a batch


# ---------------------------------------------------------------------------
# deprecation shims
# ---------------------------------------------------------------------------


def test_engine_pattern_shim_warns_and_matches(store_and_truth):
    store, T, ds = store_and_truth
    E = eng.Engine(store, cap=512, backend="jnp")
    s_, p_, o_ = map(int, ds.ids[4])
    cfg = ExecConfig(backend="jnp", cap=512)
    cases = [
        (s_, p_, o_), (s_, p_, None), (None, p_, o_), (s_, None, o_),
        (s_, None, None), (None, None, o_), (None, p_, None),
    ]
    for c in cases:
        with pytest.warns(DeprecationWarning):
            legacy = E.pattern(*c)
        new = E.compile(
            TriplePatternQ(*(t if t else None for t in c)), cfg
        )()
        if isinstance(legacy, bool):
            assert legacy == new
        elif isinstance(legacy, dict):
            assert {k: np.asarray(v).tolist() for k, v in legacy.items()} == {
                k: np.asarray(v).tolist() for k, v in new.items()
            }
        else:
            assert np.asarray(legacy).tolist() == np.asarray(new).tolist()


def test_engine_join_shim_warns_and_matches(store_and_truth):
    store, T, ds = store_and_truth
    E = eng.Engine(store, cap=512, backend="jnp")
    p1, o1 = int(ds.ids[0][1]), int(ds.ids[0][2])
    p2, o2 = int(ds.ids[1][1]), int(ds.ids[1][2])
    cfg = ExecConfig(backend="jnp", cap=512, cap_y=256)
    with pytest.warns(DeprecationWarning):
        legacy = E.join("A", p1=p1, c1=o1, vpos1="s", p2=p2, c2=o2, vpos2="s")
    new = E.compile(JoinQ("A", "s", "s", p1=p1, c1=o1, p2=p2, c2=o2), cfg)()
    assert legacy.tolist() == new.tolist()
    # the legacy per-call backend= override must keep working in the shim
    with pytest.warns(DeprecationWarning):
        legacy_be = E.join(
            "A", p1=p1, c1=o1, vpos1="s", p2=p2, c2=o2, vpos2="s",
            backend="jnp",
        )
    assert legacy_be.tolist() == new.tolist()
    with pytest.warns(DeprecationWarning):
        legacy = E.join("E", p1=p1, c1=o1, vpos1="s", vpos2="o")
    new = E.compile(JoinQ("E", "s", "o", p1=p1, c1=o1), cfg)()
    assert {
        k: {kk: vv.tolist() for kk, vv in v.items()} for k, v in legacy.items()
    } == {
        k: {kk: vv.tolist() for kk, vv in v.items()} for k, v in new.items()
    }


def test_execute_bgp_shim_warns_and_matches(store_and_truth):
    store, T, ds = store_and_truth
    E = eng.Engine(store)
    p_ = int(ds.ids[6][1])
    pats = [optimizer.TriplePattern("?s", p_, "?o")]
    with pytest.warns(DeprecationWarning):
        legacy = optimizer.execute_bgp(store, pats, cap=512)
    new = E.compile(
        BgpQ((TriplePatternQ("?s", p_, "?o"),)),
        ExecConfig(backend="jnp", cap=512),
    )()
    assert {k: sorted(v.tolist()) for k, v in legacy.items()} == {
        k: sorted(v.tolist()) for k, v in new.items()
    }
