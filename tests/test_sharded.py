"""Multi-device tests (run in a subprocess so XLA_FLAGS can set a fake
device count before jax initializes — the main pytest process stays at 1
device for everything else)."""

import os
import subprocess
import sys

import pytest

DRIVER = os.path.join(os.path.dirname(__file__), "sharded_driver.py")


@pytest.mark.parametrize("case", ["engine", "compress", "sortedset_union", "moe_shmap"])
def test_sharded_case(case):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = (
        os.path.join(os.path.dirname(__file__), "..", "src")
        + os.pathsep + env.get("PYTHONPATH", "")
    )
    r = subprocess.run(
        [sys.executable, DRIVER, case], env=env, capture_output=True, text=True,
        timeout=600,
    )
    assert r.returncode == 0, f"{case} failed:\n{r.stdout[-3000:]}\n{r.stderr[-3000:]}"
