"""Randomized differential suite: ``execute_bgp`` vs a brute-force numpy
BGP evaluator (nested loops over the dense triple set).

Covers the paths the hand-written optimizer tests miss: unbounded-``?p``
patterns riding bound and unbound positions, fully-free (cartesian-product)
patterns, repeated variables across patterns, and empty results — on both
scan backends and with the SP/OP predicate index enabled and disabled.
"""

import numpy as np
import pytest

from repro.core import k2triples
from repro.core.optimizer import TriplePattern, execute_bgp
from repro.data import rdf


@pytest.fixture(scope="module")
def small_store():
    ds = rdf.generate(220, n_subjects=16, n_preds=5, n_objects=18, seed=17)
    store = k2triples.from_id_triples(
        ds.ids, n_so=ds.n_so, n_subjects=ds.n_subjects,
        n_objects=ds.n_objects, n_preds=ds.n_preds,
    )
    return store, list(map(tuple, ds.ids.tolist())), ds


def _oracle_bgp(T, patterns):
    """Brute-force: enumerate all variable assignments consistent with T."""
    sols = [dict()]
    for pat in patterns:
        new = []
        for b in sols:
            for (s, p, o) in T:
                bb = dict(b)
                ok = True
                for term, val in ((pat.s, s), (pat.p, p), (pat.o, o)):
                    if isinstance(term, str):
                        if term in bb and bb[term] != val:
                            ok = False
                            break
                        bb[term] = val
                    elif term != val:
                        ok = False
                        break
                if ok:
                    new.append(bb)
        sols = new
    keys = sorted({k for s in sols for k in s})
    return {tuple(s[k] for k in keys) for s in sols}, keys


def _got_set(bindings):
    keys = sorted(bindings)
    if not keys:
        return set(), []
    arr = np.stack([bindings[k] for k in keys], axis=1)
    return set(map(tuple, arr.tolist())), keys


def _random_patterns(rng, ds, T, n_pats):
    """Random BGP: terms are constants (often drawn from real triples, so
    results are usually nonempty) or variables from a small shared pool.
    Always has at least one variable overall (execute_bgp rejects fully
    ground queries by contract)."""
    pool = ["?a", "?b", "?c", "?x"]
    while True:
        pats = []
        for _ in range(n_pats):
            s_, p_, o_ = T[rng.integers(0, len(T))]
            terms = []
            for pos, const, extent in (
                ("s", s_, ds.n_subjects), ("p", p_, ds.n_preds),
                ("o", o_, ds.n_objects),
            ):
                r = rng.random()
                if r < 0.45:
                    terms.append(pool[rng.integers(0, len(pool))])
                elif r < 0.85:
                    terms.append(int(const))
                else:  # sometimes a random (possibly miss) constant
                    terms.append(int(rng.integers(1, extent + 1)))
            pats.append(TriplePattern(*terms))
        if any(p.variables for p in pats):
            return pats


@pytest.mark.parametrize("backend", ["pallas", "jnp"])
@pytest.mark.parametrize("with_index", [True, False])
def test_random_bgps_match_oracle(small_store, backend, with_index):
    store, T, ds = small_store
    if not with_index:
        store = store.__class__(**{**store.__dict__, "pred_index": None})
    rng = np.random.default_rng(99 if with_index else 100)
    for case in range(25):
        pats = _random_patterns(rng, ds, T, int(rng.integers(1, 4)))
        got, keys = _got_set(
            execute_bgp(store, pats, cap=4096, backend=backend)
        )
        exp, ekeys = _oracle_bgp(T, pats)
        if exp:  # an empty oracle result may come back as empty columns
            assert keys == ekeys, (case, pats)
        assert got == exp or (not exp and not got), (case, pats, got, exp)


@pytest.mark.parametrize("backend", ["pallas", "jnp"])
def test_unbounded_pred_chain(small_store, backend):
    """?p on every pattern: the pruned resolve end-to-end."""
    store, T, ds = small_store
    subs = {t[0] for t in T}
    s, p, o = next(t for t in T if t[2] in subs)
    pats = [
        TriplePattern(s, "?p1", "?x"),
        TriplePattern("?x", "?p2", "?y"),
    ]
    got, keys = _got_set(execute_bgp(store, pats, backend=backend))
    exp, ekeys = _oracle_bgp(T, pats)
    assert keys == ekeys
    assert got == exp


@pytest.mark.parametrize("backend", ["pallas", "jnp"])
def test_cartesian_product_plan(small_store, backend):
    """Two disconnected patterns: the optimizer must cross-product them."""
    store, T, ds = small_store
    s1, p1, _ = T[0]
    _, p2, o2 = T[-1]
    pats = [
        TriplePattern(s1, p1, "?x"),
        TriplePattern("?y", p2, o2),
    ]
    got, keys = _got_set(execute_bgp(store, pats, backend=backend))
    exp, ekeys = _oracle_bgp(T, pats)
    assert keys == ekeys
    assert got == exp


@pytest.mark.parametrize("backend", ["pallas", "jnp"])
def test_fully_free_pattern(small_store, backend):
    """(?a, ?b, ?c) joined to a selective pattern — the enumeration path."""
    store, T, ds = small_store
    s, p, o = T[3]
    pats = [
        TriplePattern(s, p, "?c"),
        TriplePattern("?c", "?b", "?d"),
        TriplePattern("?e", "?f", "?g"),  # fully free, cartesian
    ]
    # keep the oracle tractable: only run when the cross product is small
    exp, ekeys = _oracle_bgp(T, pats[:2])
    if len(exp) * len(T) > 50_000:
        pytest.skip("oracle cross product too large")
    got, keys = _got_set(execute_bgp(store, pats, cap=4096, backend=backend))
    exp3, ekeys3 = _oracle_bgp(T, pats)
    assert keys == ekeys3
    assert got == exp3


def test_ground_only_bgp_rejected(small_store):
    """Fully ground queries are ASK-shaped; the columnar API refuses them."""
    store, T, ds = small_store
    s, p, o = T[0]
    with pytest.raises(ValueError):
        execute_bgp(store, [TriplePattern(s, p, o)])
    # ground patterns MIXED with variable patterns act as filters
    got = execute_bgp(store, [TriplePattern(s, p, o), TriplePattern(s, p, "?x")])
    assert sorted(got["?x"].tolist()) == sorted(
        oo for (ss, pp, oo) in T if ss == s and pp == p
    )
    got = execute_bgp(
        store, [TriplePattern(s, p, ds.n_objects + 1), TriplePattern(s, p, "?x")]
    )
    assert len(got["?x"]) == 0


def test_empty_result(small_store):
    store, T, ds = small_store
    pats = [
        TriplePattern(ds.n_subjects + 1, "?p", "?x"),  # out-of-range subject
        TriplePattern("?x", "?q", "?y"),
    ]
    got = execute_bgp(store, pats)
    assert all(len(v) == 0 for v in got.values())
