"""Trainer: loss decreases, auto-resume, torn checkpoints, elastic reshard,
grad accumulation, straggler watchdog."""

import os
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.data.tokens import TokenStream
from repro.models import transformer as tf
from repro.train import checkpoint as ckpt, optim, trainer

CFG = tf.TransformerCfg(
    name="t", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_head=8,
    d_ff=64, vocab=64, chunk_q=8, chunk_kv=16,
)


def _batches(seed=0, batch=8, seq=16):
    ts = TokenStream(64, seq, seed=seed)
    while True:
        yield {k: jnp.asarray(v) for k, v in ts.batch(batch).items()}


@pytest.fixture(scope="module")
def params():
    return tf.init(CFG, jax.random.PRNGKey(0))


def test_loss_decreases_and_resume(params):
    with tempfile.TemporaryDirectory() as d:
        tc = trainer.TrainerConfig(ckpt_dir=d, ckpt_every=10, log_every=100)
        t = trainer.Trainer(tc, lambda p, b: tf.loss_fn(CFG, p, b), optim.adamw(1e-3), params)
        assert not t.try_resume()
        hist = t.run(_batches(), 20, log=lambda s: None)
        assert hist[-1]["loss"] < hist[0]["loss"]

        t2 = trainer.Trainer(tc, lambda p, b: tf.loss_fn(CFG, p, b), optim.adamw(1e-3), params)
        assert t2.try_resume() and t2.step_num == 20
        # resumed params match saved
        for a, b in zip(jax.tree.leaves(t.params), jax.tree.leaves(t2.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_torn_checkpoint_skipped(params):
    with tempfile.TemporaryDirectory() as d:
        tc = trainer.TrainerConfig(ckpt_dir=d, ckpt_every=1000, log_every=100)
        t = trainer.Trainer(tc, lambda p, b: tf.loss_fn(CFG, p, b), optim.adamw(1e-3), params)
        state = {"params": t.params, "opt": t.opt_state}
        ckpt.save(d, 10, state)
        ckpt.save(d, 20, state)
        with open(os.path.join(d, "step_000000020", "manifest.json"), "w") as f:
            f.write("{torn")
        got = ckpt.restore_latest(d, state)
        assert got is not None and got[1] == 10


def test_gc_tmp_cleans_crashed_writes():
    with tempfile.TemporaryDirectory() as d:
        os.makedirs(os.path.join(d, "step_000000005.tmp-abc"))
        assert ckpt.gc_tmp(d) == 1
        assert ckpt.published_steps(d) == []


def test_elastic_reshard(params):
    """Restore a checkpoint onto different shardings (mesh change)."""
    with tempfile.TemporaryDirectory() as d:
        state = {"params": params}
        ckpt.save(d, 1, state)
        mesh = jax.make_mesh((1,), ("data",))
        from jax.sharding import NamedSharding, PartitionSpec as P

        sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), state)
        restored, step = ckpt.reshard_restore(d, 1, state, sh)
        assert step == 1
        for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_grad_accum_matches_big_batch(params):
    """accum=2 over half-batches == one full batch (linear loss scaling)."""
    ts = TokenStream(64, 16, seed=7)
    b = ts.batch(8)
    full = {k: jnp.asarray(v) for k, v in b.items()}
    micro = {k: jnp.asarray(v).reshape(2, 4, 16) for k, v in b.items()}

    loss_fn = lambda p, b: tf.loss_fn(CFG, p, b)
    opt = optim.sgd(0.0)  # lr 0: isolate gradient computation
    s1 = trainer.make_train_step(loss_fn, opt, grad_accum=1)
    s2 = trainer.make_train_step(loss_fn, opt, grad_accum=2)
    _, _, m1 = jax.jit(s1)(params, opt.init(params), full)
    _, _, m2 = jax.jit(s2)(params, opt.init(params), micro)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-2
    assert abs(float(m1["grad_norm"]) - float(m2["grad_norm"])) / float(m1["grad_norm"]) < 0.05


def test_straggler_watchdog():
    w = trainer.StragglerWatchdog(factor=3.0)
    for i in range(10):
        assert not w.observe(i, 0.1)
    assert w.observe(10, 1.0)  # 10x median
    assert w.flagged and w.flagged[0][0] == 10


def test_adafactor_layerwise_equivalence(rng):
    """Layer-sliced adafactor == whole-tensor adafactor (per-layer slices)."""
    opt = optim.adafactor(1e-2)
    p = {"w": jnp.asarray(rng.standard_normal((4, 8, 16)), jnp.float32)}
    g = {"w": jnp.asarray(rng.standard_normal((4, 8, 16)), jnp.float32) * 0.1}
    s = opt.init(p)
    p1, s1 = jax.jit(opt.update)(g, s, p)
    # reference: run each layer slice independently
    opt2 = optim.adafactor(1e-2)
    for l in range(4):
        pl = {"w": p["w"][l]}
        gl = {"w": g["w"][l]}
        sl = opt2.init(pl)
        pl2, _ = jax.jit(opt2.update)(gl, sl, pl)
        np.testing.assert_allclose(
            np.asarray(p1["w"][l]), np.asarray(pl2["w"]), rtol=1e-5, atol=1e-6
        )
