"""GNN zoo: losses finite, E(3)/E(n) invariance, SO(3) substrate exactness,
neighbor sampler contract."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.data import graphs as G
from repro.models.gnn import common as C, egnn, equiformer_v2 as eq2, graphcast, mace, so3

ROT = np.array(
    [[np.cos(0.3), -np.sin(0.3), 0], [np.sin(0.3), np.cos(0.3), 0], [0, 0, 1]],
    np.float32,
)


def _jnp(batch):
    return jax.tree.map(lambda x: jnp.asarray(x) if isinstance(x, np.ndarray) else x, batch)


@pytest.fixture(scope="module")
def cora_like():
    g = G.random_graph(100, 400, 16, n_classes=7, seed=1)
    return _jnp(G.to_batch(g, 7))


@pytest.fixture(scope="module")
def molecules():
    return _jnp(G.molecule_batch(4, 8, 16, seed=2))


def test_egnn_loss_and_invariance(cora_like):
    cfg = egnn.EGNNCfg(n_layers=2, d_hidden=32, in_dim=16, out_dim=7)
    p = egnn.init(cfg, jax.random.PRNGKey(0))
    loss, g = jax.value_and_grad(lambda p: egnn.loss_fn(cfg, p, cora_like))(p)
    assert np.isfinite(float(loss))
    out1 = egnn.forward(cfg, p, cora_like)
    out2 = egnn.forward(cfg, p, cora_like._replace(positions=cora_like.positions @ ROT.T))
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-4, atol=1e-4)


def test_graphcast_loss(cora_like):
    cfg = graphcast.GraphCastCfg(n_layers=2, d_hidden=32, in_dim=16, edge_dim=4, out_dim=7)
    p = graphcast.init(cfg, jax.random.PRNGKey(0))
    loss = graphcast.loss_fn(cfg, p, cora_like)
    assert np.isfinite(float(loss))


def test_mace_energy_invariance(molecules):
    cfg = mace.MACECfg(n_layers=2, d_hidden=16, l_max=2, correlation=3, n_rbf=4)
    p = mace.init(cfg, jax.random.PRNGKey(0))
    loss, _ = jax.value_and_grad(lambda p: mace.loss_fn(cfg, p, molecules))(p)
    assert np.isfinite(float(loss))
    e1 = mace.forward(cfg, p, molecules)
    e2 = mace.forward(cfg, p, molecules._replace(positions=molecules.positions @ jnp.asarray(ROT.T)))
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), rtol=2e-3, atol=2e-3)


def test_equiformer_v2_invariance(molecules):
    cfg = eq2.EquiformerV2Cfg(n_layers=2, d_hidden=8, l_max=3, m_max=2, n_heads=2, n_rbf=4)
    p = eq2.init(cfg, jax.random.PRNGKey(0))
    loss, _ = jax.value_and_grad(lambda p: eq2.loss_fn(cfg, p, molecules))(p)
    assert np.isfinite(float(loss))
    e1 = eq2.forward(cfg, p, molecules)
    e2 = eq2.forward(cfg, p, molecules._replace(positions=molecules.positions @ jnp.asarray(ROT.T)))
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), rtol=2e-3, atol=2e-3)


def test_so3_wigner_exact(rng):
    L_MAX = 4
    v = rng.standard_normal((32, 3))
    v /= np.linalg.norm(v, axis=1, keepdims=True)
    Y = so3.real_sph_harm(jnp.asarray(v), L_MAX)
    R, Rinv = so3.align_to_z(jnp.asarray(v), L_MAX)
    Yz = so3.real_sph_harm(jnp.asarray(np.tile([0, 0, 1.0], (32, 1))), L_MAX)
    err = np.abs(np.asarray(jnp.einsum("eab,eb->ea", R, Y)) - np.asarray(Yz)).max()
    assert err < 1e-4
    eye = np.einsum("eab,ecb->eac", np.asarray(R), np.asarray(R))
    assert np.abs(eye - np.eye(so3.irrep_dim(L_MAX))).max() < 1e-5


def test_so3_cg_equivariance(rng):
    l1, l2, l3 = 1, 2, 2
    Cg = so3.cg_real(l1, l2, l3)
    v = rng.standard_normal((16, 3))
    v /= np.linalg.norm(v, axis=1, keepdims=True)
    Y1 = np.asarray(so3.real_sph_harm(jnp.asarray(v), l1))[:, 1:4]
    Y2 = np.asarray(so3.real_sph_harm(jnp.asarray(v), l2))[:, 4:9]
    prod = np.einsum("abc,ea,eb->ec", Cg, Y1, Y2)
    w = np.array([[0.3, -0.5, 0.81]])
    w /= np.linalg.norm(w)
    Rfix, _ = so3.align_to_z(jnp.asarray(w), 2)
    Rl = lambda l: np.asarray(Rfix)[0][l * l : (l + 1) ** 2, l * l : (l + 1) ** 2]
    prod_rot = np.einsum("abc,ea,eb->ec", Cg, Y1 @ Rl(1).T, Y2 @ Rl(2).T)
    np.testing.assert_allclose(prod_rot, prod @ Rl(2).T, rtol=1e-4, atol=1e-5)


def test_neighbor_sampler_contract(rng):
    g = G.random_graph(5000, 40000, 32, n_classes=7, seed=3)
    samp = G.NeighborSampler(g, (5, 3))
    seeds = np.arange(64)
    sb = samp.sample(seeds)
    # static padded sizes
    assert sb.node_feat.shape[0] == 64 * 6 * 4
    assert sb.edge_src.shape[0] == 64 * 5 + 64 * 5 * 3
    # every real edge's endpoints are valid nodes
    e = sb.edge_mask
    assert (sb.edge_src[e] < sb.node_mask.sum()).all()
    # labels only on seeds
    assert (sb.labels >= 0).sum() <= len(seeds)
    # and a GNN trains on the block
    cfg = egnn.EGNNCfg(n_layers=2, d_hidden=16, in_dim=32, out_dim=7)
    p = egnn.init(cfg, jax.random.PRNGKey(0))
    loss = egnn.loss_fn(cfg, p, _jnp(sb))
    assert np.isfinite(float(loss))


def test_segment_mp_vs_dense(rng):
    """segment_sum message passing == dense adjacency matmul."""
    n, e = 30, 120
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    h = rng.standard_normal((n, 8)).astype(np.float32)
    agg = np.asarray(
        C.scatter_edges(jnp.asarray(h)[jnp.asarray(src)], jnp.asarray(dst), n)
    )
    A = np.zeros((n, n), np.float32)
    np.add.at(A, (dst, src), 1.0)
    np.testing.assert_allclose(agg, A @ h, rtol=1e-5, atol=1e-5)
