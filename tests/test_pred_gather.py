"""Differential harness for the ``pred_gather`` ragged-gather kernels:
Pallas (interpret) vs the jnp refs (``ref.pred_gather_ref`` /
``ref.pred_gather_dac_ref``) vs ``predindex._gather_traced`` vs the
fixed-width baseline vs a numpy oracle — over real ``predindex.build``
stores so BOTH on-device layouts ("dac" multi-level chunks + flag bitmaps,
"fixed" byte-packed) are exercised on the same lists.

Degree shapes covered: degree-0 entities, singletons, random mid-degree
rows, a max-degree hub subject AND hub object, and (with ``n_preds`` large)
gaps > 255 so the DAC payload goes multi-level.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import predindex
from repro.kernels import ops, pred_gather, ref

from oracle import assert_scan_result, assert_results_identical

SUBJ = 48
OBJ = 16
R = SUBJ + OBJ  # entity rows in the shared SP/OP arena

LAYOUTS = ("dac", "fixed")


def _random_store(rng, n_preds: int, *, hub_degree: int | None = None):
    """Random per-subject sorted predicate lists -> a real BuiltPredIndex.

    Subject 1 is forced empty (degree 0) and subject 2 is a hub at
    ``hub_degree`` (default min(n_preds, 40)); every triple reuses object
    ids 1..OBJ so the OP half gets hub objects for free.
    """
    hub = min(n_preds, 40) if hub_degree is None else hub_degree
    triples = []
    for s in range(1, SUBJ + 1):
        if s == 1:
            continue  # degree-0 entity
        if s == 2:
            d = hub
        else:
            kind = rng.integers(0, 4)
            d = 0 if kind == 0 else int(rng.integers(1, min(n_preds, 18) + 1))
        if d == 0:
            continue
        preds = np.sort(rng.choice(n_preds, d, replace=False)) + 1
        objs = rng.integers(1, OBJ + 1, d)
        for p, o in zip(preds, objs):
            triples.append((s, int(p), int(o)))
    ids = np.asarray(triples, np.int64).reshape(-1, 3)
    return predindex.build(
        ids, n_subjects=SUBJ, n_objects=OBJ, n_preds=n_preds
    )


def _kernel_call(bi, layout, rows, cap, block_q):
    dev, meta = bi.select(layout)
    if layout == "dac":
        return pred_gather.pred_gather_dac(
            jnp.asarray(rows), dev.offsets, dev.words, dev.degs, dev.flags,
            dev.frank, levels=meta.levels,
            level_byte_start=meta.level_byte_start,
            flag_word_start=meta.flag_word_start, deg_width=meta.deg_width,
            rows_per_block=meta.rows_per_block, cap=cap, block_q=block_q,
            interpret=True,
        )
    return pred_gather.pred_gather(
        jnp.asarray(rows), dev.offsets, dev.words,
        bytes_per_pred=meta.bytes_per_pred, cap=cap, block_q=block_q,
        interpret=True,
    )


def _ref_call(bi, layout, rows, cap):
    dev, meta = bi.select(layout)
    if layout == "dac":
        return ref.pred_gather_dac_ref(
            rows, dev.offsets, dev.words, dev.degs, dev.flags, dev.frank,
            levels=meta.levels, level_byte_start=meta.level_byte_start,
            flag_word_start=meta.flag_word_start, deg_width=meta.deg_width,
            rows_per_block=meta.rows_per_block, cap=cap,
        )
    return ref.pred_gather_ref(
        rows, dev.offsets, dev.words, bytes_per_pred=meta.bytes_per_pred,
        cap=cap,
    )


@pytest.mark.parametrize("layout", LAYOUTS)
@pytest.mark.parametrize("n_preds", [40, 3000])  # 1-byte and 2-byte widths
@pytest.mark.parametrize("cap", [4, 32])
def test_pred_gather_kernel_vs_refs(n_preds, cap, layout):
    rng = np.random.default_rng(n_preds + cap)
    for rep in range(4):
        bi = _random_store(rng, n_preds)
        rows = rng.integers(0, R, 64).astype(np.int32)
        rows[:2] = (0, 1)  # force the degree-0 entity and the hub into view
        kout = _kernel_call(bi, layout, rows, cap, block_q=32)
        rout = _ref_call(bi, layout, rows, cap)
        tout = predindex._gather_traced(
            bi.select(layout)[1], bi.select(layout)[0], rows, cap
        )
        assert_results_identical(tuple(kout), tuple(rout), f"kernel-vs-ref[{rep}]")
        assert_results_identical(
            tuple(kout), tuple(tout), f"kernel-vs-traced[{rep}]"
        )
        ids, valid, count, ovf = (np.asarray(a) for a in kout)
        for i, r_ in enumerate(rows):
            truth = np.asarray(bi.host_list(int(r_)), np.int32)
            assert_scan_result(
                ids[i], valid[i], count[i], ovf[i], truth, cap,
                f"oracle[{rep},{i}]",
            )


@pytest.mark.parametrize("cap", [8, 64])
def test_pred_gather_dac_multi_level(cap):
    """Gaps > 255 (and > 65535): the DAC payload goes multi-level and the
    flag-bitmap rank walk is on the decode path."""
    rng = np.random.default_rng(99)
    bi = _random_store(rng, 70000, hub_degree=48)
    assert bi.meta.levels >= 2, bi.meta  # the whole point of this test
    rows = rng.integers(0, R, 64).astype(np.int32)
    rows[:2] = (0, 1)
    kout = _kernel_call(bi, "dac", rows, cap, block_q=32)
    rout = _ref_call(bi, "dac", rows, cap)
    fout = _kernel_call(bi, "fixed", rows, cap, block_q=32)
    assert_results_identical(tuple(kout), tuple(rout), "kernel-vs-ref")
    assert_results_identical(tuple(kout), tuple(fout), "dac-vs-fixed")
    ids, valid, count, ovf = (np.asarray(a) for a in kout)
    for i, r_ in enumerate(rows):
        truth = np.asarray(bi.host_list(int(r_)), np.int32)
        assert_scan_result(
            ids[i], valid[i], count[i], ovf[i], truth, cap, f"oracle[{i}]"
        )


@pytest.mark.parametrize("backend", ["pallas", "jnp"])
def test_layouts_bit_identical(backend):
    """The compressed layout is invisible to callers: gather_batch output
    over "dac" == over "fixed", on both traversal backends."""
    rng = np.random.default_rng(7)
    bi = _random_store(rng, 300)
    rows = rng.integers(0, R, 32).astype(np.int32)
    out = {}
    for layout in LAYOUTS:
        dev, meta = bi.select(layout)
        out[layout] = predindex.gather_batch(meta, dev, rows, 16, backend)
    assert_results_identical(
        tuple(out["dac"]), tuple(out["fixed"]), f"layout-flip[{backend}]"
    )


@pytest.mark.parametrize("layout", LAYOUTS)
def test_ops_entry_pads_and_clips(layout):
    """ops.pred_gather_index: non-multiple batch sizes + out-of-range rows."""
    rng = np.random.default_rng(0)
    bi = _random_store(rng, 40)
    dev, meta = bi.select(layout)
    rows = np.array([0, R - 1, 5, -3, R + 9], np.int32)  # odd length + OOR
    ids, valid, count, ovf = ops.pred_gather_index(meta, dev, rows, cap=8)
    assert ids.shape == (5, 8)
    clipped = np.clip(rows, 0, R - 1)
    for i, r_ in enumerate(clipped):
        truth = np.asarray(bi.host_list(int(r_)), np.int32)
        assert_scan_result(
            np.asarray(ids[i]), np.asarray(valid[i]), int(count[i]),
            bool(ovf[i]), truth, 8, f"row{i}",
        )


@pytest.mark.parametrize("layout", LAYOUTS)
def test_gather_batch_backend_parity(monkeypatch, layout):
    """predindex.gather_batch honors the env flag and both backends agree."""
    rng = np.random.default_rng(5)
    bi = _random_store(rng, 40)
    dev, meta = bi.select(layout)
    rows = rng.integers(0, R, 32).astype(np.int32)
    out = {}
    for be in ("jnp", "pallas"):
        monkeypatch.setenv("REPRO_SCAN_BACKEND", be)
        out[be] = predindex.gather_batch(meta, dev, rows, 16)
    assert_results_identical(tuple(out["jnp"]), tuple(out["pallas"]), "env-flip")


def test_measured_bits_near_analytic():
    """The DAC layout is real: measured device bits for the index land
    within 1.25x of the analytic DAC(b=8) figure plus the (cheap)
    compressed row-pointer side."""
    rng = np.random.default_rng(11)
    bi = _random_store(rng, 40)
    measured_payload = bi.stats.payload_bits
    # analytic counts 9 bits per chunk (8 + flag); measured stores 8-bit
    # chunks word-padded + word-aligned flag bitmaps + their rank blocks
    assert measured_payload <= 1.25 * bi.stats.dac_bits + 3 * 32
    # and the whole measured index is far below the fixed-width fallback
    total = bi.stats.payload_bits + bi.stats.offsets_bits
    fixed = bi.stats.fixed_payload_bits + bi.stats.fixed_offsets_bits
    assert total < fixed
