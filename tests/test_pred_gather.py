"""Differential harness for the ``pred_gather`` ragged-gather kernel:
Pallas (interpret) vs ``ref.pred_gather_ref`` vs ``predindex._gather_traced``
vs a numpy oracle, over randomized CSR indexes at both payload widths.

Shapes are held fixed across repetitions (offsets length, padded words
length) so the whole sweep reuses one compiled program per configuration.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import predindex
from repro.core.predindex import PredIndex, PredIndexMeta
from repro.kernels import ops, pred_gather, ref

from oracle import assert_scan_result, assert_results_identical

R = 64  # entity rows
W = 640  # padded payload words (covers R rows × 18 entries at either width)


def _random_index(rng, n_preds: int):
    """Random ragged sorted lists -> (PredIndexMeta, PredIndex, host lists)."""
    bpp = 1 if n_preds <= 0xFF else 2
    lists = []
    for _ in range(R):
        kind = rng.integers(0, 4)
        if kind == 0:
            lists.append(np.zeros(0, np.int64))  # empty row
        elif kind == 1:
            lists.append(np.sort(rng.choice(n_preds, 1, replace=False)))
        else:
            d = int(rng.integers(1, min(n_preds, 18) + 1))
            lists.append(np.sort(rng.choice(n_preds, d, replace=False)))
    offsets = np.zeros(R + 1, np.int64)
    offsets[1:] = np.cumsum([len(l) for l in lists])
    payload = (
        np.concatenate(lists) if offsets[-1] else np.zeros(0, np.int64)
    ).astype(np.uint32)
    per_word = 4 // bpp
    padded = np.zeros(W * per_word, np.uint32)
    padded[: payload.shape[0]] = payload
    shifts = np.arange(per_word, dtype=np.uint64) * 8 * bpp
    words = np.bitwise_or.reduce(
        padded.reshape(W, per_word).astype(np.uint64) << shifts[None, :], axis=1
    ).astype(np.uint32)
    meta = PredIndexMeta(
        n_subjects=R, n_objects=0, n_preds=n_preds, bytes_per_pred=bpp,
        max_degree=max((len(l) for l in lists), default=0),
    )
    index = PredIndex(offsets=jnp.asarray(offsets, jnp.int32),
                      words=jnp.asarray(words))
    return meta, index, lists


@pytest.mark.parametrize("n_preds", [40, 3000])  # 1-byte and 2-byte payloads
@pytest.mark.parametrize("cap", [4, 32])
def test_pred_gather_kernel_vs_refs(n_preds, cap):
    rng = np.random.default_rng(n_preds + cap)
    for rep in range(8):
        meta, index, lists = _random_index(rng, n_preds)
        rows = rng.integers(0, R, 64).astype(np.int32)
        kout = pred_gather.pred_gather(
            jnp.asarray(rows), index.offsets, index.words,
            bytes_per_pred=meta.bytes_per_pred, cap=cap, block_q=32,
            interpret=True,
        )
        rout = ref.pred_gather_ref(
            rows, index.offsets, index.words,
            bytes_per_pred=meta.bytes_per_pred, cap=cap,
        )
        tout = predindex._gather_traced(meta, index, rows, cap)
        assert_results_identical(tuple(kout), tuple(rout), f"kernel-vs-ref[{rep}]")
        assert_results_identical(
            tuple(kout), tuple(tout), f"kernel-vs-traced[{rep}]"
        )
        ids, valid, count, ovf = (np.asarray(a) for a in kout)
        for i, r_ in enumerate(rows):
            truth = np.asarray(lists[r_], np.int32)
            assert_scan_result(
                ids[i], valid[i], count[i], ovf[i], truth, cap,
                f"oracle[{rep},{i}]",
            )


def test_ops_entry_pads_and_clips():
    """ops.pred_gather_index: non-multiple batch sizes + out-of-range rows."""
    rng = np.random.default_rng(0)
    meta, index, lists = _random_index(rng, 40)
    rows = np.array([0, R - 1, 5, -3, R + 9], np.int32)  # odd length + OOR
    ids, valid, count, ovf = ops.pred_gather_index(meta, index, rows, cap=8)
    assert ids.shape == (5, 8)
    clipped = np.clip(rows, 0, R - 1)
    for i, r_ in enumerate(clipped):
        truth = np.asarray(lists[r_], np.int32)
        assert_scan_result(
            np.asarray(ids[i]), np.asarray(valid[i]), int(count[i]),
            bool(ovf[i]), truth, 8, f"row{i}",
        )


def test_gather_batch_backend_parity(monkeypatch):
    """predindex.gather_batch honors the env flag and both backends agree."""
    rng = np.random.default_rng(5)
    meta, index, _ = _random_index(rng, 40)
    rows = rng.integers(0, R, 32).astype(np.int32)
    out = {}
    for be in ("jnp", "pallas"):
        monkeypatch.setenv("REPRO_SCAN_BACKEND", be)
        out[be] = predindex.gather_batch(meta, index, rows, 16)
    assert_results_identical(tuple(out["jnp"]), tuple(out["pallas"]), "env-flip")
