"""Train an equivariant GNN (EGNN or MACE) on batched molecule graphs.

    PYTHONPATH=src python examples/gnn_molecules.py --arch egnn --steps 50

Shows the GNN substrate end-to-end: point clouds -> kNN graphs ->
segment-sum message passing -> per-graph energy regression, with the same
Trainer (checkpoints, watchdog) as the LM path.
"""

import argparse
import sys

sys.path.insert(0, "src")

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get
from repro.data import graphs as G
from repro.launch.programs import GNN_MODULES
from repro.train import optim
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="egnn", choices=sorted(GNN_MODULES))
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="ckpt/gnn_mol")
    args = ap.parse_args()

    spec = get(args.arch)
    mod = GNN_MODULES[args.arch]
    cfg = spec.smoke_cfg
    if hasattr(cfg, "in_dim"):
        cfg = dataclasses.replace(cfg, in_dim=8, out_dim=1)

    params = mod.init(cfg, jax.random.PRNGKey(0))
    print(f"{cfg.name}: {sum(x.size for x in jax.tree.leaves(params))/1e3:.1f}K params")

    i = [0]

    def batches():
        while True:
            b = G.molecule_batch(args.batch, 8, 16, seed=i[0])
            i[0] += 1
            yield jax.tree.map(
                lambda x: jnp.asarray(x) if isinstance(x, np.ndarray) else x, b
            )

    tr = Trainer(
        TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=100, log_every=10),
        lambda p, b: mod.loss_fn(cfg, p, b),
        optim.adamw(3e-3),
        params,
    )
    hist = tr.run(batches(), args.steps)
    print(f"energy MSE: {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
