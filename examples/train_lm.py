"""End-to-end LM training driver with fault tolerance.

Small-by-default so it runs on this CPU container; on a pod, pass
``--arch tinyllama-1.1b --full`` (1.1B params) and real steps.

    PYTHONPATH=src python examples/train_lm.py --steps 200

Demonstrates: data pipeline -> loss/grad -> optimizer -> atomic checkpoints
-> kill/resume (run it twice: the second run resumes from the checkpoint).
"""

import argparse
import sys

sys.path.insert(0, "src")

import jax

from repro.configs import get
from repro.data.tokens import TokenStream
from repro.models import transformer as tfm
from repro.train import optim
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--full", action="store_true", help="full config (pod scale)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="ckpt/train_lm")
    args = ap.parse_args()

    spec = get(args.arch)
    cfg = spec.cfg if args.full else spec.smoke_cfg
    params = tfm.init(cfg, jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"{cfg.name}: {n_params/1e6:.1f}M params, vocab {cfg.vocab}")

    ts = TokenStream(cfg.vocab, args.seq, seed=0)

    def batches():
        import jax.numpy as jnp

        while True:
            yield {k: jnp.asarray(v) for k, v in ts.batch(args.batch).items()}

    tr = Trainer(
        TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=50, log_every=10),
        lambda p, b: tfm.loss_fn(cfg, p, b),
        optim.adamw(1e-3),
        params,
        on_straggler=lambda step, dt: print(f"  [watchdog] slow step {step}: {dt*1e3:.0f} ms"),
    )
    if tr.try_resume():
        print(f"resumed at step {tr.step_num}")
    hist = tr.run(batches(), args.steps)
    print(f"loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
