"""Quickstart: N3 text -> dictionary -> k²-triples store -> SPARQL patterns.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys

sys.path.insert(0, "src")

from repro.core import engine, k2triples
from repro.data import rdf

N3 = """
<http://ex/alice>   <http://ex/knows>    <http://ex/bob> .
<http://ex/alice>   <http://ex/knows>    <http://ex/carol> .
<http://ex/bob>     <http://ex/knows>    <http://ex/carol> .
<http://ex/carol>   <http://ex/worksAt>  <http://ex/acme> .
<http://ex/bob>     <http://ex/worksAt>  <http://ex/acme> .
<http://ex/acme>    <http://ex/locatedIn> <http://ex/berlin> .
"""


def main() -> None:
    triples = rdf.parse_n3(N3)
    store = k2triples.from_string_triples(triples)
    d = store.dictionary
    E = engine.Engine(store, cap=64)
    print(
        f"store: {store.n_triples} triples, {store.n_preds} predicates, "
        f"matrix side {store.meta.side}, structure {store.stats.total_bits} bits "
        f"({store.stats.total_bits / store.n_triples:.1f} bits/triple)"
    )

    alice = d.encode_subject("http://ex/alice")
    knows = d.encode_predicate("http://ex/knows")
    works = d.encode_predicate("http://ex/worksAt")
    acme = d.encode_object("http://ex/acme")

    # (S, P, ?O): who does alice know?
    out = E.pattern(alice, knows, None)
    print("alice knows:", [d.decode_object(int(o)) for o in out])

    # (?S, P, O): who works at acme?
    out = E.pattern(None, works, acme)
    print("works at acme:", [d.decode_subject(int(s)) for s in out])

    # (S, ?P, ?O): everything about alice
    out = E.pattern(alice, None, None)
    for p, objs in out.items():
        print(f"alice --{d.decode_predicate(p)}--> ",
              [d.decode_object(int(o)) for o in objs])

    # join A (SO cross-join): ?X such that alice knows ?X and ?X works at acme
    xs = E.join("A", p1=knows, c1=alice, vpos1="o", p2=works, c2=acme, vpos2="s")
    print("alice knows ∩ works-at-acme:", [d.decode_object(int(x)) for x in xs])


if __name__ == "__main__":
    main()
