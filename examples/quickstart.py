"""Quickstart: N3 text -> dictionary -> k²-triples store -> compiled plans.

    PYTHONPATH=src python examples/quickstart.py

Queries are declarative (``TriplePatternQ`` / ``JoinQ``), execution knobs
live in one frozen ``ExecConfig``, and ``Engine.compile`` returns a cached
``Plan`` — compile once, run many.
"""

import sys

sys.path.insert(0, "src")

from repro.core import engine, k2triples
from repro.core.query import ExecConfig, JoinQ, TriplePatternQ
from repro.data import rdf

N3 = """
<http://ex/alice>   <http://ex/knows>    <http://ex/bob> .
<http://ex/alice>   <http://ex/knows>    <http://ex/carol> .
<http://ex/bob>     <http://ex/knows>    <http://ex/carol> .
<http://ex/carol>   <http://ex/worksAt>  <http://ex/acme> .
<http://ex/bob>     <http://ex/worksAt>  <http://ex/acme> .
<http://ex/acme>    <http://ex/locatedIn> <http://ex/berlin> .
"""


def main() -> None:
    triples = rdf.parse_n3(N3)
    store = k2triples.from_string_triples(triples)
    d = store.dictionary
    E = engine.Engine(store)
    cfg = ExecConfig.from_env(cap=64)  # the one-time env-flag fold-in
    print(
        f"store: {store.n_triples} triples, {store.n_preds} predicates, "
        f"matrix side {store.meta.side}, structure {store.stats.total_bits} bits "
        f"({store.stats.total_bits / store.n_triples:.1f} bits/triple)"
    )

    alice = d.encode_subject("http://ex/alice")
    knows = d.encode_predicate("http://ex/knows")
    works = d.encode_predicate("http://ex/worksAt")
    acme = d.encode_object("http://ex/acme")

    # (S, P, ?O): who does alice know?
    plan = E.compile(TriplePatternQ(alice, knows, "?who"), cfg)
    print("alice knows:", [d.decode_object(int(o)) for o in plan()])

    # the same compiled plan serves any (S, P, ?O) query — here as a batch
    bob = d.encode_subject("http://ex/bob")
    for objs in plan({"s": [alice, bob], "p": [knows, works]}):
        print("  batched lane:", [d.decode_object(int(o)) for o in objs])

    # (?S, P, O): who works at acme?
    out = E.compile(TriplePatternQ("?s", works, acme), cfg)()
    print("works at acme:", [d.decode_subject(int(s)) for s in out])

    # (S, ?P, ?O): everything about alice
    out = E.compile(TriplePatternQ(alice, "?p", "?o"), cfg)()
    for p, objs in out.items():
        print(f"alice --{d.decode_predicate(p)}--> ",
              [d.decode_object(int(o)) for o in objs])

    # join A (SO cross-join): ?X such that alice knows ?X and ?X works at acme
    xs = E.compile(
        JoinQ("A", vpos1="o", vpos2="s", p1=knows, c1=alice, p2=works, c2=acme),
        cfg,
    )()
    print("alice knows ∩ works-at-acme:", [d.decode_object(int(x)) for x in xs])


if __name__ == "__main__":
    main()
