"""Serve SPARQL triple patterns from a compressed in-memory store.

    PYTHONPATH=src python examples/serve_sparql.py --triples 100000

Builds a synthetic store (paper Table 1 ratios), compiles one batched
serve plan, then replays a skewed multi-tenant query trace through the
streaming broker (`repro.launch.broker`) — the paper's "full-in-memory
RDF engine" as a production serving loop.
"""

import argparse
import sys

sys.path.insert(0, "src")


def main() -> None:
    from repro.launch import serve

    sys.argv = [sys.argv[0]] + sys.argv[1:]
    serve.main()


if __name__ == "__main__":
    main()
